//! The ChunkAttention two-phase-partition (TPP) decode kernel (§3.2) over
//! the prefix-tree KV cache.
//!
//! ## The 2D (head × chunk) schedule
//!
//! The paper assigns *(head, chunk)* pairs to CUDA thread blocks; the
//! production CPU kernel [`tpp_attention_2d`] is the same partition mapped
//! onto the worker pool:
//!
//! 1. **Chunk-first phase (Algorithm 1), parallel over (head × chunk-run)**
//!    — the shared entries of the [`TreeContext`] are split into *runs* of
//!    [`RUN_CHUNKS`] consecutive chunks. Each (head, run) task streams its
//!    chunks' K/V once for every covered query row and writes independent
//!    `(O, m, n)^{(C)}` partials into a per-task slice of the scratch
//!    buffers ([`Tpp2dScratch`]).
//! 2. **Sequence-first phase (Algorithm 2), parallel over (head ×
//!    sequence)** — each (head, row) task `attn_reduce`-merges the run
//!    partials covering its row *in run-index order*, attends the row's
//!    private tail chunks, and normalises.
//!
//! Run boundaries depend only on the context — never on the pool size — and
//! every merge walks the runs in a fixed order, so the output is
//! **bit-identical for every thread count**. With `heads × runs` and
//! `heads × batch` tasks the pool stays busy even when `heads < workers`
//! (small models, GQA-style configs), where the older head-only partition
//! left most workers idle.
//!
//! ## Storage dtypes
//!
//! Every public kernel dispatches once per call on the tree's
//! [`KvDtype`] to a body monomorphized over the storage element
//! ([`crate::kvcache::KvElem`]): K/V rows widen to f32 registers inside the
//! 8-row micro-kernel, accumulation stays f32, and the partial buffers are
//! always f32. Half-precision storage halves the streamed chunk bytes —
//! the dominant traffic of the bandwidth-bound chunk-first phase.
//!
//! ## Ablation variants
//!
//! - [`tpp_attention`] — head-partitioned fused kernel (previous
//!   production): chunk-first batching with the `attn_reduce` merge fused
//!   right after each `partial_attn`, one task per head. Kept as the
//!   1D-partition baseline.
//! - [`tpp_attention_buffered`] — Algorithms 1 and 2 verbatim,
//!   single-threaded: the chunk-first phase writes `(O, m, n)^{(C)}`
//!   partials to memory, the sequence-first phase restores and merges them.
//!   Cross-checks both parallel variants.
//! - [`tpp_attention_seq_only`] — sequence-first only (no cross-sequence
//!   batching): every chunk is processed once per covered sequence. This is
//!   what a prefix-aware cache *without* TPP costs, isolating the kernel
//!   contribution from the memory-sharing contribution.

use super::online::{attend_block_scaled, attn_reduce, OnlineState};
use super::Queries;
use crate::kvcache::{Bf16, CtxEntry, KvDtype, KvElem, PrefixTree, TreeContext, F16, I8};
use crate::util::threadpool::ThreadPool;
use std::time::Instant;

/// Reusable scratch for the TPP kernels: no allocation on the decode path.
pub struct TppScratch {
    /// Running max per (head, row): `[heads * batch]`.
    m: Vec<f32>,
    /// Normaliser per (head, row).
    n: Vec<f32>,
    /// Per-head weight scratch: `[heads * chunk_size]`.
    w: Vec<f32>,
    heads: usize,
    batch: usize,
    chunk_size: usize,
}

impl TppScratch {
    pub fn new(shape: &crate::kvcache::KvShape, max_batch: usize) -> Self {
        TppScratch {
            m: vec![0.0; shape.heads * max_batch],
            n: vec![0.0; shape.heads * max_batch],
            w: vec![0.0; shape.heads * shape.chunk_size],
            heads: shape.heads,
            batch: max_batch,
            chunk_size: shape.chunk_size,
        }
    }

    fn ensure(&mut self, heads: usize, batch: usize, chunk_size: usize) {
        if heads * batch > self.m.len() {
            self.m.resize(heads * batch, 0.0);
            self.n.resize(heads * batch, 0.0);
        }
        if heads * chunk_size > self.w.len() {
            self.w.resize(heads * chunk_size, 0.0);
        }
        self.heads = heads;
        self.batch = batch;
        self.chunk_size = chunk_size;
    }
}

/// Head-partitioned (1D) fused TPP kernel — the previous production kernel,
/// kept as the ablation baseline for [`tpp_attention_2d`]. Output
/// `[heads, batch, head_dim]`, rows in `ctx.seq_order`.
pub fn tpp_attention(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    pool: &ThreadPool,
    scratch: &mut TppScratch,
    out: &mut [f32],
) {
    match tree.shape().dtype {
        KvDtype::F32 => tpp_attention_impl::<f32>(tree, ctx, q, pool, scratch, out),
        KvDtype::F16 => tpp_attention_impl::<F16>(tree, ctx, q, pool, scratch, out),
        KvDtype::Bf16 => tpp_attention_impl::<Bf16>(tree, ctx, q, pool, scratch, out),
        KvDtype::Int8 => tpp_attention_impl::<I8>(tree, ctx, q, pool, scratch, out),
    }
}

fn tpp_attention_impl<E: KvElem>(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    pool: &ThreadPool,
    scratch: &mut TppScratch,
    out: &mut [f32],
) {
    let shape = tree.shape();
    let b = ctx.seq_order.len();
    assert_eq!(q.heads, shape.heads);
    assert_eq!(q.head_dim, shape.head_dim);
    assert_eq!(q.batch, b);
    assert_eq!(out.len(), shape.heads * b * shape.head_dim);
    scratch.ensure(shape.heads, b, shape.chunk_size);
    let d = shape.head_dim;
    let scale = q.scale();

    // Per-head slices are disjoint; hand raw base addresses to the workers.
    let out_addr = out.as_mut_ptr() as usize;
    let m_addr = scratch.m.as_mut_ptr() as usize;
    let n_addr = scratch.n.as_mut_ptr() as usize;
    let w_addr = scratch.w.as_mut_ptr() as usize;
    let c = shape.chunk_size;

    pool.parallel_for(shape.heads, |h| {
        // Safety: each head index owns a disjoint slice of out/m/n/w, and
        // parallel_for joins before `out`/`scratch` are touched again.
        let o_head = unsafe {
            std::slice::from_raw_parts_mut((out_addr as *mut f32).add(h * b * d), b * d)
        };
        let m_head =
            unsafe { std::slice::from_raw_parts_mut((m_addr as *mut f32).add(h * b), b) };
        let n_head =
            unsafe { std::slice::from_raw_parts_mut((n_addr as *mut f32).add(h * b), b) };
        let w = unsafe { std::slice::from_raw_parts_mut((w_addr as *mut f32).add(h * c), c) };
        let q_head = q.head(h);

        let mut state = OnlineState { m: m_head, n: n_head, o: o_head, head_dim: d };
        state.reset();

        // Phase 1 — chunk first: shared chunks, query rows batched so each
        // K/V chunk is streamed once for all covered sequences (Eqn. 1).
        for e in ctx.shared() {
            let chunk = tree.chunk(e.chunk);
            let rows = e.end - e.start;
            attend_block_scaled(
                &q_head[e.start * d..e.end * d],
                rows,
                d,
                chunk.k_head::<E>(&shape, h),
                chunk.k_head_scale(&shape, h),
                chunk.v_head::<E>(&shape, h),
                chunk.v_head_scale(&shape, h),
                chunk.len(),
                scale,
                &mut OnlineState {
                    m: &mut state.m[e.start..e.end],
                    n: &mut state.n[e.start..e.end],
                    o: &mut state.o[e.start * d..e.end * d],
                    head_dim: d,
                },
                w,
            );
        }

        // Phase 2 — sequence first: private chunks, one row each (Eqn. 2's
        // reduce is fused into attend_block).
        for e in ctx.private() {
            let chunk = tree.chunk(e.chunk);
            let r = e.start;
            attend_block_scaled(
                &q_head[r * d..(r + 1) * d],
                1,
                d,
                chunk.k_head::<E>(&shape, h),
                chunk.k_head_scale(&shape, h),
                chunk.v_head::<E>(&shape, h),
                chunk.v_head_scale(&shape, h),
                chunk.len(),
                scale,
                &mut OnlineState {
                    m: &mut state.m[r..r + 1],
                    n: &mut state.n[r..r + 1],
                    o: &mut state.o[r * d..(r + 1) * d],
                    head_dim: d,
                },
                w,
            );
        }

        state.finish();
    });
}

/// Shared chunks per chunk-first task. A pure function of the context (not
/// of the pool size): partial sums — and therefore results — stay
/// bit-identical across thread counts. Four 64-token chunks ≈ 256 shared
/// tokens per task, enough work to amortise dispatch.
pub const RUN_CHUNKS: usize = 4;

/// One chunk-first run: a contiguous slice of the shared entries plus the
/// union of the row intervals it covers and its offset into the per-head
/// partial buffers.
#[derive(Debug, Clone, Copy)]
struct Run {
    e_lo: usize,
    e_hi: usize,
    row_lo: usize,
    row_hi: usize,
    offset: usize,
}

/// Reusable scratch for [`tpp_attention_2d`]: the run schedule, a CSR index
/// of private entries by row, and the `(O, m, n)^{(C)}` partial buffers.
/// No allocation on the decode path once warmed up.
#[derive(Default)]
pub struct Tpp2dScratch {
    shared: Vec<CtxEntry>,
    private: Vec<CtxEntry>,
    /// CSR offsets into `private` by query row: entries of row `r` are
    /// `private[private_row_ptr[r]..private_row_ptr[r + 1]]`.
    private_row_ptr: Vec<usize>,
    runs: Vec<Run>,
    /// Partial rows across all runs (the per-head buffer stride).
    rows_total: usize,
    /// Partial maxima `[heads * rows_total]`.
    part_m: Vec<f32>,
    /// Partial normalisers `[heads * rows_total]`.
    part_n: Vec<f32>,
    /// Unnormalised partial outputs `[heads * rows_total * head_dim]`.
    part_o: Vec<f32>,
}

impl Tpp2dScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Build the deterministic run schedule for `ctx` and size the partial
    /// buffers for `heads` × `head_dim`.
    fn prepare(&mut self, ctx: &TreeContext, heads: usize, d: usize) {
        self.shared.clear();
        self.private.clear();
        for e in &ctx.entries {
            if e.is_shared() {
                self.shared.push(*e);
            } else {
                self.private.push(*e);
            }
        }
        // CSR of private entries by row (stable sort keeps context order
        // within a row, so the merge order is schedule-independent).
        let b = ctx.seq_order.len();
        self.private.sort_by_key(|e| e.start);
        self.private_row_ptr.clear();
        self.private_row_ptr.resize(b + 1, 0);
        for e in &self.private {
            self.private_row_ptr[e.start + 1] += 1;
        }
        for r in 0..b {
            self.private_row_ptr[r + 1] += self.private_row_ptr[r];
        }
        // Runs of RUN_CHUNKS consecutive shared entries.
        self.runs.clear();
        let mut offset = 0;
        let mut i = 0;
        while i < self.shared.len() {
            let j = (i + RUN_CHUNKS).min(self.shared.len());
            let slice = &self.shared[i..j];
            let row_lo = slice.iter().map(|e| e.start).min().unwrap();
            let row_hi = slice.iter().map(|e| e.end).max().unwrap();
            self.runs.push(Run { e_lo: i, e_hi: j, row_lo, row_hi, offset });
            offset += row_hi - row_lo;
            i = j;
        }
        self.rows_total = offset;
        let need = heads * offset;
        if self.part_m.len() < need {
            self.part_m.resize(need, 0.0);
            self.part_n.resize(need, 0.0);
        }
        if self.part_o.len() < need * d {
            self.part_o.resize(need * d, 0.0);
        }
    }
}

thread_local! {
    /// Per-worker weight scratch for the 2D schedule. Tasks are transient
    /// (heads × runs of them per call), so per-task buffers would churn;
    /// one buffer per pool worker is allocation-free after warmup.
    static WBUF: std::cell::RefCell<Vec<f32>> = std::cell::RefCell::new(Vec::new());
}

fn with_wbuf<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    WBUF.with(|cell| {
        let mut w = cell.borrow_mut();
        if w.len() < len {
            w.resize(len, 0.0);
        }
        f(&mut w[..])
    })
}

/// The production TPP kernel: the paper's 2D *(head × chunk)* partition
/// mapped onto the worker pool (see the module docs). Output
/// `[heads, batch, head_dim]`, rows in `ctx.seq_order`; bit-identical for
/// every pool size.
pub fn tpp_attention_2d(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    pool: &ThreadPool,
    scratch: &mut Tpp2dScratch,
    out: &mut [f32],
) {
    match tree.shape().dtype {
        KvDtype::F32 => tpp_attention_2d_impl::<f32>(tree, ctx, q, pool, scratch, out),
        KvDtype::F16 => tpp_attention_2d_impl::<F16>(tree, ctx, q, pool, scratch, out),
        KvDtype::Bf16 => tpp_attention_2d_impl::<Bf16>(tree, ctx, q, pool, scratch, out),
        KvDtype::Int8 => tpp_attention_2d_impl::<I8>(tree, ctx, q, pool, scratch, out),
    }
}

fn tpp_attention_2d_impl<E: KvElem>(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    pool: &ThreadPool,
    scratch: &mut Tpp2dScratch,
    out: &mut [f32],
) {
    let shape = tree.shape();
    let b = ctx.seq_order.len();
    assert_eq!(q.heads, shape.heads);
    assert_eq!(q.head_dim, shape.head_dim);
    assert_eq!(q.batch, b);
    assert_eq!(out.len(), shape.heads * b * shape.head_dim);
    if b == 0 {
        return;
    }
    let heads = shape.heads;
    let d = shape.head_dim;
    let c = shape.chunk_size;
    let scale = q.scale();

    scratch.prepare(ctx, heads, d);
    let rows_total = scratch.rows_total;
    let nruns = scratch.runs.len();
    // Split the scratch borrow: the schedule is read-only inside the tasks
    // while the partial buffers are handed out as disjoint raw slices.
    let Tpp2dScratch { shared, private, private_row_ptr, runs, part_m, part_n, part_o, .. } =
        scratch;
    let shared: &[CtxEntry] = shared;
    let private: &[CtxEntry] = private;
    let private_row_ptr: &[usize] = private_row_ptr;
    let runs: &[Run] = runs;
    let m_addr = part_m.as_mut_ptr() as usize;
    let n_addr = part_n.as_mut_ptr() as usize;
    let o_addr = part_o.as_mut_ptr() as usize;
    let out_addr = out.as_mut_ptr() as usize;

    // Phase boundaries are timed on every call (two monotonic reads per
    // phase) and reported through the thread-local side channel in
    // `util::trace`; the engine drains them into the per-phase histograms
    // after each decode. Cost is well inside the bench's run-to-run noise.
    let t_phase1 = Instant::now();

    // Phase 1 — chunk first (Algorithm 1), one task per (head, run): stream
    // each shared chunk's K/V once for all covered rows, writing
    // (O, m, n)^{(C)} partials into the task's disjoint buffer slice.
    //
    // Sticky schedule: run indices are stable while the tree shape is (the
    // common case across consecutive decode steps), and slab addresses are
    // stable for a chunk's lifetime — so pinning each (head, run) task to
    // a fixed worker keeps that run's K/V slabs hot in one core's private
    // cache across steps (the CoDec/RelayAttention locality argument).
    // Phase 2 stays dynamic: its per-row merge tasks are cheap and uneven,
    // so balancing matters more than reuse. Numerics are identical under
    // either schedule (each task owns a disjoint slice; merge order in
    // phase 2 is fixed by run index, not worker).
    if nruns > 0 {
        pool.parallel_for_sticky(heads * nruns, |t| {
            let h = t / nruns;
            let run = &runs[t % nruns];
            let span = run.row_hi - run.row_lo;
            let base = h * rows_total + run.offset;
            // Safety: each (head, run) task owns the disjoint
            // [base, base + span) slice of the partial buffers, and
            // parallel_for joins before the scratch is touched again.
            let m_p =
                unsafe { std::slice::from_raw_parts_mut((m_addr as *mut f32).add(base), span) };
            let n_p =
                unsafe { std::slice::from_raw_parts_mut((n_addr as *mut f32).add(base), span) };
            let o_p = unsafe {
                std::slice::from_raw_parts_mut((o_addr as *mut f32).add(base * d), span * d)
            };
            m_p.fill(f32::NEG_INFINITY);
            n_p.fill(0.0);
            o_p.fill(0.0);
            let q_head = q.head(h);
            with_wbuf(c, |w| {
                for e in &shared[run.e_lo..run.e_hi] {
                    let chunk = tree.chunk(e.chunk);
                    let rel = e.start - run.row_lo;
                    let rows = e.end - e.start;
                    attend_block_scaled(
                        &q_head[e.start * d..e.end * d],
                        rows,
                        d,
                        chunk.k_head::<E>(&shape, h),
                        chunk.k_head_scale(&shape, h),
                        chunk.v_head::<E>(&shape, h),
                        chunk.v_head_scale(&shape, h),
                        chunk.len(),
                        scale,
                        &mut OnlineState {
                            m: &mut m_p[rel..rel + rows],
                            n: &mut n_p[rel..rel + rows],
                            o: &mut o_p[rel * d..(rel + rows) * d],
                            head_dim: d,
                        },
                        w,
                    );
                }
            });
        });
    }

    let t_phase2 = Instant::now();

    // Phase 2 — sequence first (Algorithm 2), one task per (head, row):
    // merge the run partials covering the row in run-index order (fixed, so
    // results are schedule-independent), then attend the row's private
    // chunks and normalise.
    pool.parallel_for(heads * b, |t| {
        let h = t / b;
        let r = t % b;
        // Safety: each (head, row) task owns one disjoint output row;
        // phase 1 has fully joined, so the partial buffers are read-only.
        let o_row = unsafe {
            std::slice::from_raw_parts_mut((out_addr as *mut f32).add((h * b + r) * d), d)
        };
        o_row.fill(0.0);
        let mut m = f32::NEG_INFINITY;
        let mut n = 0.0f32;
        for run in runs {
            if r < run.row_lo || r >= run.row_hi {
                continue;
            }
            let idx = h * rows_total + run.offset + (r - run.row_lo);
            let m_c = unsafe { *(m_addr as *const f32).add(idx) };
            if m_c == f32::NEG_INFINITY {
                continue; // row inside the run's span but not covered
            }
            let n_c = unsafe { *(n_addr as *const f32).add(idx) };
            let o_c =
                unsafe { std::slice::from_raw_parts((o_addr as *const f32).add(idx * d), d) };
            attn_reduce(&mut m, &mut n, o_row, m_c, n_c, o_c);
        }
        let q_head = q.head(h);
        with_wbuf(c, |w| {
            for e in &private[private_row_ptr[r]..private_row_ptr[r + 1]] {
                let chunk = tree.chunk(e.chunk);
                attend_block_scaled(
                    &q_head[r * d..(r + 1) * d],
                    1,
                    d,
                    chunk.k_head::<E>(&shape, h),
                    chunk.k_head_scale(&shape, h),
                    chunk.v_head::<E>(&shape, h),
                    chunk.v_head_scale(&shape, h),
                    chunk.len(),
                    scale,
                    &mut OnlineState {
                        m: std::slice::from_mut(&mut m),
                        n: std::slice::from_mut(&mut n),
                        o: &mut o_row[..],
                        head_dim: d,
                    },
                    w,
                );
            }
        });
        if n > 0.0 {
            let inv = 1.0 / n;
            for x in o_row.iter_mut() {
                *x *= inv;
            }
        }
    });

    crate::util::trace::record_kernel_phases(
        t_phase2.duration_since(t_phase1).as_micros() as u64,
        t_phase2.elapsed().as_micros() as u64,
    );
}

/// Algorithm 1 + Algorithm 2 verbatim: chunk-first saves `(O, m, n)^{(C)}`
/// partials to memory; sequence-first restores and merges them, then
/// processes private chunks. Numerically identical to [`tpp_attention`].
pub fn tpp_attention_buffered(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    out: &mut [f32],
) {
    match tree.shape().dtype {
        KvDtype::F32 => tpp_attention_buffered_impl::<f32>(tree, ctx, q, out),
        KvDtype::F16 => tpp_attention_buffered_impl::<F16>(tree, ctx, q, out),
        KvDtype::Bf16 => tpp_attention_buffered_impl::<Bf16>(tree, ctx, q, out),
        KvDtype::Int8 => tpp_attention_buffered_impl::<I8>(tree, ctx, q, out),
    }
}

fn tpp_attention_buffered_impl<E: KvElem>(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    out: &mut [f32],
) {
    let shape = tree.shape();
    let b = ctx.seq_order.len();
    assert_eq!(q.batch, b);
    let d = shape.head_dim;
    let scale = q.scale();
    let shared: Vec<_> = ctx.shared().collect();

    // Partial buffers: for each shared chunk, (O, m, n) for its row span.
    let spans: Vec<usize> = shared.iter().map(|e| e.end - e.start).collect();
    let offsets: Vec<usize> = spans
        .iter()
        .scan(0, |acc, &s| {
            let off = *acc;
            *acc += s;
            Some(off)
        })
        .collect();
    let total_rows: usize = spans.iter().sum();

    let mut w = vec![0.0f32; shape.chunk_size];
    for h in 0..shape.heads {
        let q_head = q.head(h);
        let mut part_o = vec![0.0f32; total_rows * d];
        let mut part_m = vec![f32::NEG_INFINITY; total_rows];
        let mut part_n = vec![0.0f32; total_rows];

        // ATTNCHUNKFIRST (Algorithm 1): independent partials per chunk.
        for (ci, e) in shared.iter().enumerate() {
            let chunk = tree.chunk(e.chunk);
            let rows = e.end - e.start;
            let off = offsets[ci];
            attend_block_scaled(
                &q_head[e.start * d..e.end * d],
                rows,
                d,
                chunk.k_head::<E>(&shape, h),
                chunk.k_head_scale(&shape, h),
                chunk.v_head::<E>(&shape, h),
                chunk.v_head_scale(&shape, h),
                chunk.len(),
                scale,
                &mut OnlineState {
                    m: &mut part_m[off..off + rows],
                    n: &mut part_n[off..off + rows],
                    o: &mut part_o[off * d..(off + rows) * d],
                    head_dim: d,
                },
                w.as_mut_slice(),
            );
        }

        // ATTNSEQFIRST (Algorithm 2): per row, merge saved partials then
        // process the row's private chunks.
        for r in 0..b {
            let (mut m, mut n) = (f32::NEG_INFINITY, 0.0f32);
            let o_base = (h * b + r) * d;
            out[o_base..o_base + d].fill(0.0);
            // attn_reduce over saved partials covering row r.
            for (ci, e) in shared.iter().enumerate() {
                if r < e.start || r >= e.end {
                    continue;
                }
                let off = offsets[ci] + (r - e.start);
                attn_reduce(
                    &mut m,
                    &mut n,
                    &mut out[o_base..o_base + d],
                    part_m[off],
                    part_n[off],
                    &part_o[off * d..(off + 1) * d],
                );
            }
            // Private chunks of row r.
            for e in ctx.private() {
                if e.start != r {
                    continue;
                }
                let chunk = tree.chunk(e.chunk);
                let (o_lo, o_hi) = (o_base, o_base + d);
                attend_block_scaled(
                    &q_head[r * d..(r + 1) * d],
                    1,
                    d,
                    chunk.k_head::<E>(&shape, h),
                    chunk.k_head_scale(&shape, h),
                    chunk.v_head::<E>(&shape, h),
                    chunk.v_head_scale(&shape, h),
                    chunk.len(),
                    scale,
                    &mut OnlineState {
                        m: std::slice::from_mut(&mut m),
                        n: std::slice::from_mut(&mut n),
                        o: &mut out[o_lo..o_hi],
                        head_dim: d,
                    },
                    w.as_mut_slice(),
                );
            }
            if n > 0.0 {
                let inv = 1.0 / n;
                for x in &mut out[o_base..o_base + d] {
                    *x *= inv;
                }
            }
        }
    }
}

/// Sequence-first only: prefix-aware storage but NO chunk-first batching —
/// each shared chunk is re-streamed once per covered sequence. Isolates the
/// TPP kernel's contribution from PAKV's memory savings (ablation).
pub fn tpp_attention_seq_only(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    scratch: &mut TppScratch,
    out: &mut [f32],
) {
    match tree.shape().dtype {
        KvDtype::F32 => tpp_attention_seq_only_impl::<f32>(tree, ctx, q, scratch, out),
        KvDtype::F16 => tpp_attention_seq_only_impl::<F16>(tree, ctx, q, scratch, out),
        KvDtype::Bf16 => tpp_attention_seq_only_impl::<Bf16>(tree, ctx, q, scratch, out),
        KvDtype::Int8 => tpp_attention_seq_only_impl::<I8>(tree, ctx, q, scratch, out),
    }
}

fn tpp_attention_seq_only_impl<E: KvElem>(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    scratch: &mut TppScratch,
    out: &mut [f32],
) {
    let shape = tree.shape();
    let b = ctx.seq_order.len();
    assert_eq!(q.batch, b);
    scratch.ensure(shape.heads, b, shape.chunk_size);
    let d = shape.head_dim;
    let scale = q.scale();
    let w = &mut scratch.w[..shape.chunk_size];
    for h in 0..shape.heads {
        let q_head = q.head(h);
        let o_head = &mut out[h * b * d..(h + 1) * b * d];
        let m_head = &mut scratch.m[h * b..(h + 1) * b];
        let n_head = &mut scratch.n[h * b..(h + 1) * b];
        let mut state = OnlineState { m: m_head, n: n_head, o: o_head, head_dim: d };
        state.reset();
        for e in &ctx.entries {
            let chunk = tree.chunk(e.chunk);
            // One row at a time — no batching, so shared chunks are
            // re-read (end - start) times.
            for r in e.start..e.end {
                attend_block_scaled(
                    &q_head[r * d..(r + 1) * d],
                    1,
                    d,
                    chunk.k_head::<E>(&shape, h),
                    chunk.k_head_scale(&shape, h),
                    chunk.v_head::<E>(&shape, h),
                    chunk.v_head_scale(&shape, h),
                    chunk.len(),
                    scale,
                    &mut OnlineState {
                        m: &mut state.m[r..r + 1],
                        n: &mut state.n[r..r + 1],
                        o: &mut state.o[r * d..(r + 1) * d],
                        head_dim: d,
                    },
                    w,
                );
            }
        }
        state.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::oracle_attention;
    use crate::kvcache::{KvShape, PrefixTree, SeqId};
    use crate::util::rng::Pcg64;

    fn build_tree(shape: KvShape, seed: u64) -> PrefixTree {
        let mut tree = PrefixTree::new(shape);
        let sys: Vec<u32> = (0..10).collect();
        for i in 0..6u64 {
            let mut p = sys.clone();
            p.extend((0..3).map(|j| 100 + i as u32 * 10 + j));
            tree.insert_sequence(SeqId(i), &p, &mut |pos, token, k, v| {
                let mut r = Pcg64::new(seed ^ token as u64, pos as u64);
                r.fill_uniform_f32(k, -1.0, 1.0);
                r.fill_uniform_f32(v, -1.0, 1.0);
            });
        }
        tree
    }

    fn queries(shape: &KvShape, b: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        let mut q = vec![0.0; shape.heads * b * shape.head_dim];
        rng.fill_uniform_f32(&mut q, -1.0, 1.0);
        q
    }

    #[test]
    fn all_variants_agree_with_oracle() {
        let shape = KvShape::new(2, 8, 4);
        let mut tree = build_tree(shape, 5);
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        let qdata = queries(&shape, b, 17);
        let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);
        let expect = oracle_attention(&tree, &ctx, &q);

        let pool = ThreadPool::new(1);
        let mut scratch = TppScratch::new(&shape, b);

        let mut fused = vec![0.0; expect.len()];
        tpp_attention(&tree, &ctx, &q, &pool, &mut scratch, &mut fused);

        let mut buffered = vec![0.0; expect.len()];
        tpp_attention_buffered(&tree, &ctx, &q, &mut buffered);

        let mut seq_only = vec![0.0; expect.len()];
        tpp_attention_seq_only(&tree, &ctx, &q, &mut scratch, &mut seq_only);

        let mut scratch2d = Tpp2dScratch::new();
        let mut two_d = vec![0.0; expect.len()];
        tpp_attention_2d(&tree, &ctx, &q, &pool, &mut scratch2d, &mut two_d);

        for i in 0..expect.len() {
            assert!((fused[i] - expect[i]).abs() < 2e-4, "fused idx {i}");
            assert!((buffered[i] - expect[i]).abs() < 2e-4, "buffered idx {i}");
            assert!((seq_only[i] - expect[i]).abs() < 2e-4, "seq_only idx {i}");
            assert!((two_d[i] - expect[i]).abs() < 2e-4, "2d idx {i}");
            // Buffered and fused follow different summation orders but must
            // agree tightly.
            assert!((buffered[i] - fused[i]).abs() < 1e-4, "variants idx {i}");
        }
    }

    #[test]
    fn all_variants_agree_with_oracle_at_half_precision() {
        // The oracle gathers the *stored* (already quantised) rows and
        // widens them, so the kernel-vs-oracle tolerance is set by f32
        // accumulation, not by the storage dtype — int8 included: the
        // oracle's read_f32 dequantizes with the same exact
        // convert-and-multiply the kernel's widening load uses.
        for dtype in [KvDtype::F16, KvDtype::Bf16, KvDtype::Int8] {
            let shape = KvShape::new(2, 8, 4).with_dtype(dtype);
            let mut tree = build_tree(shape, 5);
            let ctx = tree.context();
            let b = ctx.seq_order.len();
            let qdata = queries(&shape, b, 17);
            let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);
            let expect = oracle_attention(&tree, &ctx, &q);

            let pool = ThreadPool::new(2);
            let mut scratch = TppScratch::new(&shape, b);
            let mut fused = vec![0.0; expect.len()];
            tpp_attention(&tree, &ctx, &q, &pool, &mut scratch, &mut fused);
            let mut scratch2d = Tpp2dScratch::new();
            let mut two_d = vec![0.0; expect.len()];
            tpp_attention_2d(&tree, &ctx, &q, &pool, &mut scratch2d, &mut two_d);
            for i in 0..expect.len() {
                assert!((fused[i] - expect[i]).abs() < 2e-4, "{dtype:?} fused idx {i}");
                assert!((two_d[i] - expect[i]).abs() < 2e-4, "{dtype:?} 2d idx {i}");
            }
        }
    }

    #[test]
    fn two_d_schedule_is_bit_identical_across_thread_counts() {
        let shape = KvShape::new(4, 8, 4);
        let mut tree = build_tree(shape, 13);
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        let qdata = queries(&shape, b, 23);
        let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);
        let mut reference: Option<Vec<f32>> = None;
        for workers in [1usize, 2, 3, 8] {
            let pool = ThreadPool::new(workers);
            let mut scratch = Tpp2dScratch::new();
            let mut out = vec![0.0; shape.heads * b * shape.head_dim];
            tpp_attention_2d(&tree, &ctx, &q, &pool, &mut scratch, &mut out);
            match &reference {
                None => reference = Some(out),
                Some(r) => assert_eq!(r, &out, "workers={workers} diverged"),
            }
        }
    }

    #[test]
    fn two_d_handles_deep_trees_spanning_many_runs() {
        // A long shared prefix (many chunks → several runs per head) with
        // nested divergence exercises run-boundary bookkeeping.
        let shape = KvShape::new(2, 8, 4);
        let mut tree = PrefixTree::new(shape);
        let sys: Vec<u32> = (0..40).collect(); // 10 chunks of 4 → 3 runs
        for i in 0..5u64 {
            let mut p = sys.clone();
            p.extend((0..(i as usize % 3 + 1)).map(|j| 500 + i as u32 * 10 + j as u32));
            tree.insert_sequence(SeqId(i), &p, &mut |pos, token, k, v| {
                let mut r = Pcg64::new(31 ^ token as u64, pos as u64);
                r.fill_uniform_f32(k, -1.0, 1.0);
                r.fill_uniform_f32(v, -1.0, 1.0);
            });
        }
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        let qdata = queries(&shape, b, 41);
        let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);
        let expect = oracle_attention(&tree, &ctx, &q);
        let pool = ThreadPool::new(4);
        let mut scratch = Tpp2dScratch::new();
        let mut out = vec![0.0; expect.len()];
        tpp_attention_2d(&tree, &ctx, &q, &pool, &mut scratch, &mut out);
        for i in 0..expect.len() {
            assert!(
                (out[i] - expect[i]).abs() < 2e-4 * (1.0 + expect[i].abs()),
                "idx {i}: {} vs {}",
                out[i],
                expect[i]
            );
        }
    }

    #[test]
    fn two_d_scratch_is_reusable_across_contexts() {
        // Reuse one scratch across growing trees (decode loop pattern).
        let shape = KvShape::new(2, 8, 4);
        let mut tree = build_tree(shape, 7);
        let pool = ThreadPool::new(2);
        let mut scratch = Tpp2dScratch::new();
        for round in 0..3u64 {
            let ctx = tree.context();
            let b = ctx.seq_order.len();
            let qdata = queries(&shape, b, 50 + round);
            let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);
            let expect = oracle_attention(&tree, &ctx, &q);
            let mut out = vec![0.0; expect.len()];
            tpp_attention_2d(&tree, &ctx, &q, &pool, &mut scratch, &mut out);
            for i in 0..expect.len() {
                assert!((out[i] - expect[i]).abs() < 2e-4 * (1.0 + expect[i].abs()));
            }
            // Grow every sequence by one decoded token.
            let row = vec![0.1f32; shape.heads * shape.head_dim];
            for s in ctx.seq_order {
                tree.append_token(s, 900 + round as u32, &row, &row);
            }
        }
    }

    #[test]
    fn multithreaded_matches_single_thread() {
        let shape = KvShape::new(4, 8, 4);
        let mut tree = build_tree(shape, 9);
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        let qdata = queries(&shape, b, 31);
        let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);

        let mut one = vec![0.0; shape.heads * b * shape.head_dim];
        let mut four = vec![0.0; one.len()];
        let mut scratch = TppScratch::new(&shape, b);
        tpp_attention(&tree, &ctx, &q, &ThreadPool::new(1), &mut scratch, &mut one);
        tpp_attention(&tree, &ctx, &q, &ThreadPool::new(4), &mut scratch, &mut four);
        assert_eq!(one, four, "head partition must be deterministic");
    }

    #[test]
    fn scratch_grows_on_demand() {
        let shape = KvShape::new(2, 4, 4);
        let mut scratch = TppScratch::new(&shape, 1); // deliberately small
        let mut tree = build_tree(shape, 2);
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        let qdata = queries(&shape, b, 3);
        let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);
        let mut out = vec![0.0; shape.heads * b * shape.head_dim];
        tpp_attention(&tree, &ctx, &q, &ThreadPool::new(1), &mut scratch, &mut out);
        assert!(out.iter().any(|&x| x != 0.0));
    }
}
