//! The ChunkAttention two-phase-partition (TPP) decode kernel (§3.2) over
//! the prefix-tree KV cache.
//!
//! Three variants are provided:
//!
//! - [`tpp_attention`] — the production CPU kernel: chunk-first batching of
//!   query rows over shared chunks with the `attn_reduce` merge fused right
//!   after each `partial_attn` (§3.3: on CPU serialising the reduction is
//!   cheap, so no partial buffers are materialised), then the
//!   sequence-first pass over private tail chunks. Work is partitioned over
//!   heads on the thread pool — the CPU analogue of the paper's
//!   thread-block partition.
//! - [`tpp_attention_buffered`] — Algorithms 1 and 2 verbatim: the
//!   chunk-first phase writes `(O, m, n)^{(C)}` partials to memory, the
//!   sequence-first phase restores and merges them. Used by the ablation
//!   bench and as a cross-check of the fused variant.
//! - [`tpp_attention_seq_only`] — sequence-first only (no cross-sequence
//!   batching): every chunk is processed once per covered sequence. This is
//!   what a prefix-aware cache *without* TPP costs, isolating the kernel
//!   contribution from the memory-sharing contribution.

use super::online::{attend_block, OnlineState};
use super::Queries;
use crate::kvcache::{PrefixTree, TreeContext};
use crate::util::threadpool::ThreadPool;

/// Reusable scratch for the TPP kernels: no allocation on the decode path.
pub struct TppScratch {
    /// Running max per (head, row): `[heads * batch]`.
    m: Vec<f32>,
    /// Normaliser per (head, row).
    n: Vec<f32>,
    /// Per-head weight scratch: `[heads * chunk_size]`.
    w: Vec<f32>,
    heads: usize,
    batch: usize,
    chunk_size: usize,
}

impl TppScratch {
    pub fn new(shape: &crate::kvcache::KvShape, max_batch: usize) -> Self {
        TppScratch {
            m: vec![0.0; shape.heads * max_batch],
            n: vec![0.0; shape.heads * max_batch],
            w: vec![0.0; shape.heads * shape.chunk_size],
            heads: shape.heads,
            batch: max_batch,
            chunk_size: shape.chunk_size,
        }
    }

    fn ensure(&mut self, heads: usize, batch: usize, chunk_size: usize) {
        if heads * batch > self.m.len() {
            self.m.resize(heads * batch, 0.0);
            self.n.resize(heads * batch, 0.0);
        }
        if heads * chunk_size > self.w.len() {
            self.w.resize(heads * chunk_size, 0.0);
        }
        self.heads = heads;
        self.batch = batch;
        self.chunk_size = chunk_size;
    }
}

/// The production TPP kernel. Output `[heads, batch, head_dim]`, rows in
/// `ctx.seq_order`.
pub fn tpp_attention(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    pool: &ThreadPool,
    scratch: &mut TppScratch,
    out: &mut [f32],
) {
    let shape = tree.shape();
    let b = ctx.seq_order.len();
    assert_eq!(q.heads, shape.heads);
    assert_eq!(q.head_dim, shape.head_dim);
    assert_eq!(q.batch, b);
    assert_eq!(out.len(), shape.heads * b * shape.head_dim);
    scratch.ensure(shape.heads, b, shape.chunk_size);
    let d = shape.head_dim;
    let scale = q.scale();

    // Per-head slices are disjoint; hand raw base addresses to the workers.
    let out_addr = out.as_mut_ptr() as usize;
    let m_addr = scratch.m.as_mut_ptr() as usize;
    let n_addr = scratch.n.as_mut_ptr() as usize;
    let w_addr = scratch.w.as_mut_ptr() as usize;
    let c = shape.chunk_size;

    pool.parallel_for(shape.heads, |h| {
        // Safety: each head index owns a disjoint slice of out/m/n/w, and
        // parallel_for joins before `out`/`scratch` are touched again.
        let o_head = unsafe {
            std::slice::from_raw_parts_mut((out_addr as *mut f32).add(h * b * d), b * d)
        };
        let m_head =
            unsafe { std::slice::from_raw_parts_mut((m_addr as *mut f32).add(h * b), b) };
        let n_head =
            unsafe { std::slice::from_raw_parts_mut((n_addr as *mut f32).add(h * b), b) };
        let w = unsafe { std::slice::from_raw_parts_mut((w_addr as *mut f32).add(h * c), c) };
        let q_head = q.head(h);

        let mut state = OnlineState { m: m_head, n: n_head, o: o_head, head_dim: d };
        state.reset();

        // Phase 1 — chunk first: shared chunks, query rows batched so each
        // K/V chunk is streamed once for all covered sequences (Eqn. 1).
        for e in ctx.shared() {
            let chunk = tree.chunk(e.chunk);
            let rows = e.end - e.start;
            attend_block(
                &q_head[e.start * d..e.end * d],
                rows,
                d,
                chunk.k_head(&shape, h),
                chunk.v_head(&shape, h),
                chunk.len(),
                scale,
                &mut OnlineState {
                    m: &mut state.m[e.start..e.end],
                    n: &mut state.n[e.start..e.end],
                    o: &mut state.o[e.start * d..e.end * d],
                    head_dim: d,
                },
                w,
            );
        }

        // Phase 2 — sequence first: private chunks, one row each (Eqn. 2's
        // reduce is fused into attend_block).
        for e in ctx.private() {
            let chunk = tree.chunk(e.chunk);
            let r = e.start;
            attend_block(
                &q_head[r * d..(r + 1) * d],
                1,
                d,
                chunk.k_head(&shape, h),
                chunk.v_head(&shape, h),
                chunk.len(),
                scale,
                &mut OnlineState {
                    m: &mut state.m[r..r + 1],
                    n: &mut state.n[r..r + 1],
                    o: &mut state.o[r * d..(r + 1) * d],
                    head_dim: d,
                },
                w,
            );
        }

        state.finish();
    });
}

/// Algorithm 1 + Algorithm 2 verbatim: chunk-first saves `(O, m, n)^{(C)}`
/// partials to memory; sequence-first restores and merges them, then
/// processes private chunks. Numerically identical to [`tpp_attention`].
pub fn tpp_attention_buffered(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    out: &mut [f32],
) {
    let shape = tree.shape();
    let b = ctx.seq_order.len();
    assert_eq!(q.batch, b);
    let d = shape.head_dim;
    let scale = q.scale();
    let shared: Vec<_> = ctx.shared().collect();

    // Partial buffers: for each shared chunk, (O, m, n) for its row span.
    let spans: Vec<usize> = shared.iter().map(|e| e.end - e.start).collect();
    let offsets: Vec<usize> = spans
        .iter()
        .scan(0, |acc, &s| {
            let off = *acc;
            *acc += s;
            Some(off)
        })
        .collect();
    let total_rows: usize = spans.iter().sum();

    let mut w = vec![0.0f32; shape.chunk_size];
    for h in 0..shape.heads {
        let q_head = q.head(h);
        let mut part_o = vec![0.0f32; total_rows * d];
        let mut part_m = vec![f32::NEG_INFINITY; total_rows];
        let mut part_n = vec![0.0f32; total_rows];

        // ATTNCHUNKFIRST (Algorithm 1): independent partials per chunk.
        for (ci, e) in shared.iter().enumerate() {
            let chunk = tree.chunk(e.chunk);
            let rows = e.end - e.start;
            let off = offsets[ci];
            attend_block(
                &q_head[e.start * d..e.end * d],
                rows,
                d,
                chunk.k_head(&shape, h),
                chunk.v_head(&shape, h),
                chunk.len(),
                scale,
                &mut OnlineState {
                    m: &mut part_m[off..off + rows],
                    n: &mut part_n[off..off + rows],
                    o: &mut part_o[off * d..(off + rows) * d],
                    head_dim: d,
                },
                w.as_mut_slice(),
            );
        }

        // ATTNSEQFIRST (Algorithm 2): per row, merge saved partials then
        // process the row's private chunks.
        for r in 0..b {
            let (mut m, mut n) = (f32::NEG_INFINITY, 0.0f32);
            let o_base = (h * b + r) * d;
            out[o_base..o_base + d].fill(0.0);
            // attn_reduce over saved partials covering row r.
            for (ci, e) in shared.iter().enumerate() {
                if r < e.start || r >= e.end {
                    continue;
                }
                let off = offsets[ci] + (r - e.start);
                let m_c = part_m[off];
                let n_c = part_n[off];
                let m_new = m.max(m_c);
                let x = (m_c - m_new).exp();
                let y = if m == f32::NEG_INFINITY { 0.0 } else { (m - m_new).exp() };
                for i in 0..d {
                    out[o_base + i] = out[o_base + i] * y + part_o[off * d + i] * x;
                }
                n = n * y + n_c * x;
                m = m_new;
            }
            // Private chunks of row r.
            for e in ctx.private() {
                if e.start != r {
                    continue;
                }
                let chunk = tree.chunk(e.chunk);
                let (o_lo, o_hi) = (o_base, o_base + d);
                attend_block(
                    &q_head[r * d..(r + 1) * d],
                    1,
                    d,
                    chunk.k_head(&shape, h),
                    chunk.v_head(&shape, h),
                    chunk.len(),
                    scale,
                    &mut OnlineState {
                        m: std::slice::from_mut(&mut m),
                        n: std::slice::from_mut(&mut n),
                        o: &mut out[o_lo..o_hi],
                        head_dim: d,
                    },
                    w.as_mut_slice(),
                );
            }
            if n > 0.0 {
                let inv = 1.0 / n;
                for x in &mut out[o_base..o_base + d] {
                    *x *= inv;
                }
            }
        }
    }
}

/// Sequence-first only: prefix-aware storage but NO chunk-first batching —
/// each shared chunk is re-streamed once per covered sequence. Isolates the
/// TPP kernel's contribution from PAKV's memory savings (ablation).
pub fn tpp_attention_seq_only(
    tree: &PrefixTree,
    ctx: &TreeContext,
    q: &Queries,
    scratch: &mut TppScratch,
    out: &mut [f32],
) {
    let shape = tree.shape();
    let b = ctx.seq_order.len();
    assert_eq!(q.batch, b);
    scratch.ensure(shape.heads, b, shape.chunk_size);
    let d = shape.head_dim;
    let scale = q.scale();
    let w = &mut scratch.w[..shape.chunk_size];
    for h in 0..shape.heads {
        let q_head = q.head(h);
        let o_head = &mut out[h * b * d..(h + 1) * b * d];
        let m_head = &mut scratch.m[h * b..(h + 1) * b];
        let n_head = &mut scratch.n[h * b..(h + 1) * b];
        let mut state = OnlineState { m: m_head, n: n_head, o: o_head, head_dim: d };
        state.reset();
        for e in &ctx.entries {
            let chunk = tree.chunk(e.chunk);
            // One row at a time — no batching, so shared chunks are
            // re-read (end - start) times.
            for r in e.start..e.end {
                attend_block(
                    &q_head[r * d..(r + 1) * d],
                    1,
                    d,
                    chunk.k_head(&shape, h),
                    chunk.v_head(&shape, h),
                    chunk.len(),
                    scale,
                    &mut OnlineState {
                        m: &mut state.m[r..r + 1],
                        n: &mut state.n[r..r + 1],
                        o: &mut state.o[r * d..(r + 1) * d],
                        head_dim: d,
                    },
                    w,
                );
            }
        }
        state.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::oracle_attention;
    use crate::kvcache::{KvShape, PrefixTree, SeqId};
    use crate::util::rng::Pcg64;

    fn build_tree(shape: KvShape, seed: u64) -> PrefixTree {
        let mut tree = PrefixTree::new(shape);
        let sys: Vec<u32> = (0..10).collect();
        for i in 0..6u64 {
            let mut p = sys.clone();
            p.extend((0..3).map(|j| 100 + i as u32 * 10 + j));
            tree.insert_sequence(SeqId(i), &p, &mut |pos, token, k, v| {
                let mut r = Pcg64::new(seed ^ token as u64, pos as u64);
                r.fill_uniform_f32(k, -1.0, 1.0);
                r.fill_uniform_f32(v, -1.0, 1.0);
            });
        }
        tree
    }

    fn queries(shape: &KvShape, b: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::seeded(seed);
        let mut q = vec![0.0; shape.heads * b * shape.head_dim];
        rng.fill_uniform_f32(&mut q, -1.0, 1.0);
        q
    }

    #[test]
    fn all_variants_agree_with_oracle() {
        let shape = KvShape::new(2, 8, 4);
        let mut tree = build_tree(shape, 5);
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        let qdata = queries(&shape, b, 17);
        let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);
        let expect = oracle_attention(&tree, &ctx, &q);

        let pool = ThreadPool::new(1);
        let mut scratch = TppScratch::new(&shape, b);

        let mut fused = vec![0.0; expect.len()];
        tpp_attention(&tree, &ctx, &q, &pool, &mut scratch, &mut fused);

        let mut buffered = vec![0.0; expect.len()];
        tpp_attention_buffered(&tree, &ctx, &q, &mut buffered);

        let mut seq_only = vec![0.0; expect.len()];
        tpp_attention_seq_only(&tree, &ctx, &q, &mut scratch, &mut seq_only);

        for i in 0..expect.len() {
            assert!((fused[i] - expect[i]).abs() < 2e-4, "fused idx {i}");
            assert!((buffered[i] - expect[i]).abs() < 2e-4, "buffered idx {i}");
            assert!((seq_only[i] - expect[i]).abs() < 2e-4, "seq_only idx {i}");
            // Buffered and fused follow different summation orders but must
            // agree tightly.
            assert!((buffered[i] - fused[i]).abs() < 1e-4, "variants idx {i}");
        }
    }

    #[test]
    fn multithreaded_matches_single_thread() {
        let shape = KvShape::new(4, 8, 4);
        let mut tree = build_tree(shape, 9);
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        let qdata = queries(&shape, b, 31);
        let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);

        let mut one = vec![0.0; shape.heads * b * shape.head_dim];
        let mut four = vec![0.0; one.len()];
        let mut scratch = TppScratch::new(&shape, b);
        tpp_attention(&tree, &ctx, &q, &ThreadPool::new(1), &mut scratch, &mut one);
        tpp_attention(&tree, &ctx, &q, &ThreadPool::new(4), &mut scratch, &mut four);
        assert_eq!(one, four, "head partition must be deterministic");
    }

    #[test]
    fn scratch_grows_on_demand() {
        let shape = KvShape::new(2, 4, 4);
        let mut scratch = TppScratch::new(&shape, 1); // deliberately small
        let mut tree = build_tree(shape, 2);
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        let qdata = queries(&shape, b, 3);
        let q = Queries::new(&qdata, shape.heads, b, shape.head_dim);
        let mut out = vec![0.0; shape.heads * b * shape.head_dim];
        tpp_attention(&tree, &ctx, &q, &ThreadPool::new(1), &mut scratch, &mut out);
        assert!(out.iter().any(|&x| x != 0.0));
    }
}
