//! Naive decode attention over the monolithic cache — the paper's "Naive
//! PyTorch" baseline: per sequence, per head, a full `softmax(qKᵀ/√d)V`
//! with a materialised weight vector, streaming each sequence's entire
//! (private) K and V from memory. Dispatches on the cache dtype like every
//! other kernel so the Table 3 comparison stays fair at half precision.

use super::online::{axpy_kv, dot_kv};
use super::{out_row, Queries};
use crate::kvcache::{Bf16, KvDtype, KvElem, MonolithicKvCache, SeqId, F16, I8};

/// Output layout `[heads, batch, head_dim]`, rows in `order`.
pub fn naive_attention(cache: &MonolithicKvCache, order: &[SeqId], q: &Queries, out: &mut [f32]) {
    match cache.shape().dtype {
        KvDtype::F32 => naive_attention_impl::<f32>(cache, order, q, out),
        KvDtype::F16 => naive_attention_impl::<F16>(cache, order, q, out),
        KvDtype::Bf16 => naive_attention_impl::<Bf16>(cache, order, q, out),
        KvDtype::Int8 => naive_attention_impl::<I8>(cache, order, q, out),
    }
}

fn naive_attention_impl<E: KvElem>(
    cache: &MonolithicKvCache,
    order: &[SeqId],
    q: &Queries,
    out: &mut [f32],
) {
    let shape = cache.shape();
    assert_eq!(q.heads, shape.heads);
    assert_eq!(q.head_dim, shape.head_dim);
    assert_eq!(q.batch, order.len());
    assert_eq!(out.len(), q.heads * q.batch * q.head_dim);
    let d = shape.head_dim;
    let scale = q.scale();
    let max_len = order
        .iter()
        .map(|&s| cache.get(s).expect("sequence in cache").len)
        .max()
        .unwrap_or(0);
    let mut w = vec![0.0f32; max_len];
    for h in 0..q.heads {
        for (row, &seq) in order.iter().enumerate() {
            let s = cache.get(seq).expect("sequence in cache");
            let n = s.len;
            let k = s.k_head::<E>(&shape, h);
            let v = s.v_head::<E>(&shape, h);
            // Int8 stores unscaled quantised codes; folding the per-head
            // dequant scale into the logit (and the softmax weight, below)
            // is mathematically identical to dequantising each row first.
            // Float dtypes report 1.0, and `x * 1.0` is a bitwise no-op.
            let k_scale = s.k_head_scale(&shape, h);
            let v_scale = s.v_head_scale(&shape, h);
            let q_row = q.row(h, row);
            // Materialised weights (the "naive" part: no online softmax).
            let mut m = f32::NEG_INFINITY;
            for t in 0..n {
                let x = dot_kv(q_row, &k[t * d..(t + 1) * d]) * k_scale * scale;
                w[t] = x;
                m = m.max(x);
            }
            let mut norm = 0.0f32;
            for t in 0..n {
                let e = (w[t] - m).exp();
                w[t] = e;
                norm += e;
            }
            let o = out_row(out, q.heads, q.batch, d, h, row);
            o.fill(0.0);
            for t in 0..n {
                axpy_kv(w[t] * v_scale, &v[t * d..(t + 1) * d], o);
            }
            let inv = 1.0 / norm;
            for x in o.iter_mut() {
                *x *= inv;
            }
        }
    }
}
