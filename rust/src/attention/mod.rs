//! Decode-time self-attention kernels.
//!
//! Six implementations matching the paper's Table 3 columns, all computing
//! `softmax(Q Kᵀ / √d) V` for one decode step (one query token per
//! sequence):
//!
//! | module | Table 3 column | KV layout |
//! |---|---|---|
//! | [`naive`] | Naive | monolithic dense |
//! | [`xformers_style`] | xformers | monolithic dense |
//! | [`flash_style`] | FlashAttn | monolithic dense |
//! | [`paged`] (private pages) | PagedAttn | paged |
//! | [`paged`] (aliased pages) | PagedAttn\* | paged, shared physical pages |
//! | [`chunk_tpp`] | ChunkAttn | prefix tree (PAKV) + TPP kernel |
//!
//! The ChunkAttn row is served by the 2D-scheduled
//! [`chunk_tpp::tpp_attention_2d`] in production; the head-partitioned
//! [`chunk_tpp::tpp_attention`] and the other TPP variants remain as
//! ablation baselines (see [`chunk_tpp`] module docs).
//!
//! ## Layout
//!
//! Queries and outputs are `[heads, batch, head_dim]` (head-major) so each
//! head's query block is a contiguous `b×d` matrix — the slice
//! `Q_{i:j,:}` of Eqn. (1) is then contiguous for any sequence interval
//! `[i, j)`, which is exactly the property the prefix tree guarantees.
//!
//! Row order follows the tree context's `seq_order`; callers using the
//! monolithic/paged caches pass an explicit sequence order.

pub mod chunk_tpp;
pub mod flash_style;
pub mod naive;
pub mod online;
pub mod oracle;
pub mod paged;
pub mod xformers_style;

pub use chunk_tpp::{
    tpp_attention, tpp_attention_2d, tpp_attention_buffered, tpp_attention_seq_only, Tpp2dScratch,
    TppScratch,
};
pub use flash_style::flash_style_attention;
pub use naive::naive_attention;
pub use oracle::oracle_attention;
pub use paged::paged_attention;
pub use xformers_style::xformers_style_attention;

/// Query (and output) tensor view: `[heads, batch, head_dim]`.
#[derive(Debug, Clone, Copy)]
pub struct Queries<'a> {
    pub data: &'a [f32],
    pub heads: usize,
    pub batch: usize,
    pub head_dim: usize,
}

impl<'a> Queries<'a> {
    pub fn new(data: &'a [f32], heads: usize, batch: usize, head_dim: usize) -> Self {
        assert_eq!(data.len(), heads * batch * head_dim, "query tensor shape mismatch");
        Queries { data, heads, batch, head_dim }
    }

    /// Contiguous `[batch, head_dim]` block for one head.
    #[inline]
    pub fn head(&self, h: usize) -> &'a [f32] {
        let stride = self.batch * self.head_dim;
        &self.data[h * stride..(h + 1) * stride]
    }

    /// One query row.
    #[inline]
    pub fn row(&self, h: usize, b: usize) -> &'a [f32] {
        let base = (h * self.batch + b) * self.head_dim;
        &self.data[base..base + self.head_dim]
    }

    pub fn scale(&self) -> f32 {
        1.0 / (self.head_dim as f32).sqrt()
    }
}

/// Mutable `[heads, batch, head_dim]` output view helpers.
#[inline]
pub fn out_row(out: &mut [f32], heads: usize, batch: usize, head_dim: usize, h: usize, b: usize) -> &mut [f32] {
    debug_assert_eq!(out.len(), heads * batch * head_dim);
    let base = (h * batch + b) * head_dim;
    &mut out[base..base + head_dim]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{KvShape, MonolithicKvCache, PagedKvCache, PrefixTree, SeqId};
    use crate::util::rng::Pcg64;
    use crate::util::threadpool::ThreadPool;

    /// Build the same logical KV state in all three cache layouts plus
    /// random queries, then check every kernel against the f64 oracle.
    struct Fixture {
        shape: KvShape,
        tree: PrefixTree,
        mono: MonolithicKvCache,
        pag: PagedKvCache,
        pag_shared: PagedKvCache,
        seqs: Vec<SeqId>,
        q: Vec<f32>,
    }

    fn kv_fill(rng_seed: u64) -> impl FnMut(usize, u32, &mut [f32], &mut [f32]) {
        move |pos, token, k: &mut [f32], v: &mut [f32]| {
            // Deterministic per (pos, token): all caches store identical KV.
            let mut r = Pcg64::new(rng_seed ^ (token as u64), pos as u64);
            r.fill_uniform_f32(k, -1.0, 1.0);
            r.fill_uniform_f32(v, -1.0, 1.0);
        }
    }

    fn build_fixture(
        shape: KvShape,
        prompts: &[Vec<u32>],
        shared_hint: &[usize],
        seed: u64,
    ) -> Fixture {
        let mut tree = PrefixTree::new(shape);
        let mut mono = MonolithicKvCache::new(shape);
        let mut pag = PagedKvCache::new(shape, shape.chunk_size);
        let mut pag_shared = PagedKvCache::new(shape, shape.chunk_size);
        let mut seqs = Vec::new();
        for (i, prompt) in prompts.iter().enumerate() {
            let seq = SeqId(i as u64);
            seqs.push(seq);
            tree.insert_sequence(seq, prompt, &mut kv_fill(seed));
            mono.insert_sequence(seq, prompt, prompt.len() + 8, &mut kv_fill(seed));
            pag.insert_sequence(seq, prompt, &mut kv_fill(seed));
            if i > 0 && shared_hint[i] > 0 {
                pag_shared.insert_sequence_shared(
                    seq,
                    SeqId(0),
                    prompt,
                    shared_hint[i],
                    &mut kv_fill(seed),
                );
            } else {
                pag_shared.insert_sequence(seq, prompt, &mut kv_fill(seed));
            }
        }
        // Queries in tree context order.
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        let mut rng = Pcg64::new(seed.wrapping_add(99), 0);
        let mut q = vec![0.0f32; shape.heads * b * shape.head_dim];
        rng.fill_uniform_f32(&mut q, -1.0, 1.0);
        Fixture { shape, tree, mono, pag, pag_shared, seqs, q }
    }

    fn check_all_kernels(mut fx: Fixture, tol: f32) {
        let shape = fx.shape;
        let ctx = fx.tree.context();
        let b = ctx.seq_order.len();
        let q = Queries::new(&fx.q, shape.heads, b, shape.head_dim);

        // Oracle in tree order.
        let expect = oracle_attention(&fx.tree, &ctx, &q);

        // TPP on the tree: production 2D schedule plus the head-partitioned
        // ablation baseline.
        let pool = ThreadPool::new(1);
        let mut scratch = TppScratch::new(&shape, b);
        let mut got = vec![0.0f32; expect.len()];
        tpp_attention(&fx.tree, &ctx, &q, &pool, &mut scratch, &mut got);
        assert_close(&got, &expect, tol, "chunk_tpp");

        let mut scratch2d = Tpp2dScratch::new();
        let mut got = vec![0.0f32; expect.len()];
        tpp_attention_2d(&fx.tree, &ctx, &q, &pool, &mut scratch2d, &mut got);
        assert_close(&got, &expect, tol, "chunk_tpp_2d");

        // Dense baselines use the same row order.
        let order: Vec<SeqId> = ctx.seq_order.clone();
        let mut got = vec![0.0f32; expect.len()];
        naive_attention(&fx.mono, &order, &q, &mut got);
        assert_close(&got, &expect, tol, "naive");

        let mut got = vec![0.0f32; expect.len()];
        xformers_style_attention(&fx.mono, &order, &q, 32, &mut got);
        assert_close(&got, &expect, tol, "xformers");

        let mut got = vec![0.0f32; expect.len()];
        flash_style_attention(&fx.mono, &order, &q, 16, &mut got);
        assert_close(&got, &expect, tol, "flash");

        let mut got = vec![0.0f32; expect.len()];
        paged_attention(&fx.pag, &order, &q, &mut got);
        assert_close(&got, &expect, tol, "paged");

        let mut got = vec![0.0f32; expect.len()];
        paged_attention(&fx.pag_shared, &order, &q, &mut got);
        assert_close(&got, &expect, tol, "paged_shared");

        let _ = &fx.seqs;
    }

    fn assert_close(got: &[f32], expect: &[f32], tol: f32, what: &str) {
        assert_eq!(got.len(), expect.len());
        for (i, (g, e)) in got.iter().zip(expect).enumerate() {
            assert!(
                (g - e).abs() <= tol * (1.0 + e.abs()),
                "{what}: idx {i}: got {g}, expect {e}"
            );
        }
    }

    #[test]
    fn all_kernels_match_oracle_shared_prefixes() {
        let shape = KvShape::new(3, 8, 4);
        let sys: Vec<u32> = (100..100 + 9).collect(); // 9-token shared prefix
        let prompts: Vec<Vec<u32>> = (0..5)
            .map(|i| {
                let mut p = sys.clone();
                p.extend((0..4).map(|j| 1000 + i * 10 + j));
                p
            })
            .collect();
        let shared = vec![0, 9, 9, 9, 9];
        check_all_kernels(build_fixture(shape, &prompts, &shared, 7), 2e-4);
    }

    #[test]
    fn all_kernels_match_oracle_no_sharing() {
        let shape = KvShape::new(2, 16, 8);
        let prompts: Vec<Vec<u32>> =
            (0..4).map(|i| (0..13).map(|j| (i * 1000 + j) as u32).collect()).collect();
        let shared = vec![0; 4];
        check_all_kernels(build_fixture(shape, &prompts, &shared, 21), 2e-4);
    }

    #[test]
    fn all_kernels_match_oracle_single_sequence() {
        let shape = KvShape::new(1, 4, 4);
        let prompts = vec![(0u32..7).collect::<Vec<_>>()];
        check_all_kernels(build_fixture(shape, &prompts, &[0], 3), 2e-4);
    }

    #[test]
    fn all_kernels_match_oracle_half_precision_storage() {
        // Same fixture at f16 and bf16 storage: every layout quantises
        // identically (same fill values through the same write seam), and
        // the oracle reads the stored rows back widened — so the tolerance
        // stays accumulation-bound even at half precision.
        use crate::kvcache::KvDtype;
        let sys: Vec<u32> = (100..100 + 9).collect();
        let prompts: Vec<Vec<u32>> = (0..5)
            .map(|i| {
                let mut p = sys.clone();
                p.extend((0..4).map(|j| 1000 + i * 10 + j));
                p
            })
            .collect();
        let shared = vec![0, 9, 9, 9, 9];
        for dtype in [KvDtype::F16, KvDtype::Bf16] {
            let shape = KvShape::new(3, 8, 4).with_dtype(dtype);
            check_all_kernels(build_fixture(shape, &prompts, &shared, 7), 3e-4);
        }
    }

    #[test]
    fn all_kernels_match_oracle_nested_prefixes() {
        // s0 is a prefix of s1 which shares with s2 at a shallower depth.
        let shape = KvShape::new(2, 8, 4);
        let prompts: Vec<Vec<u32>> = vec![
            (0..8).collect(),
            (0..16).collect(),
            (0..6).chain(50..58).collect(),
        ];
        check_all_kernels(build_fixture(shape, &prompts, &[0, 8, 4], 11), 2e-4);
    }

    #[test]
    fn queries_layout_helpers() {
        let data: Vec<f32> = (0..2 * 3 * 4).map(|x| x as f32).collect();
        let q = Queries::new(&data, 2, 3, 4);
        assert_eq!(q.head(1).len(), 12);
        assert_eq!(q.row(1, 2), &[20.0, 21.0, 22.0, 23.0]);
        assert!((q.scale() - 0.5).abs() < 1e-7);
    }
}
