//! PagedAttention-style decode kernel (Kwon et al., 2023): per sequence,
//! per head, walk the page table and attend page by page with online
//! softmax. Used for both Table 3 baselines:
//!
//! - **PagedAttn**: sequences inserted with private pages — the same bytes
//!   are stored (and streamed) once per sequence.
//! - **PagedAttn\***: page tables alias shared physical pages (built via
//!   [`PagedKvCache::insert_sequence_shared`]) — the kernel is unchanged but
//!   repeated reads of the same physical page hit the hardware cache, which
//!   is precisely the effect the paper isolates with this baseline.
//!
//! Pages may be stored at any [`crate::kvcache::KvDtype`]; the kernel
//! dispatches once per call and widens rows to f32 at load.

use super::online::{attend_block_scaled, OnlineState};
use super::{out_row, Queries};
use crate::kvcache::{Bf16, KvDtype, KvElem, PagedKvCache, SeqId, F16, I8};

/// Output layout `[heads, batch, head_dim]`, rows in `order`.
pub fn paged_attention(cache: &PagedKvCache, order: &[SeqId], q: &Queries, out: &mut [f32]) {
    match cache.shape().dtype {
        KvDtype::F32 => paged_attention_impl::<f32>(cache, order, q, out),
        KvDtype::F16 => paged_attention_impl::<F16>(cache, order, q, out),
        KvDtype::Bf16 => paged_attention_impl::<Bf16>(cache, order, q, out),
        KvDtype::Int8 => paged_attention_impl::<I8>(cache, order, q, out),
    }
}

fn paged_attention_impl<E: KvElem>(
    cache: &PagedKvCache,
    order: &[SeqId],
    q: &Queries,
    out: &mut [f32],
) {
    let shape = cache.shape();
    assert_eq!(q.heads, shape.heads);
    assert_eq!(q.head_dim, shape.head_dim);
    assert_eq!(q.batch, order.len());
    let d = shape.head_dim;
    let page = cache.page_size();
    let scale = q.scale();
    let mut w = vec![0.0f32; page];
    let (mut m1, mut n1) = ([0.0f32; 1], [0.0f32; 1]);
    for h in 0..q.heads {
        for (row, &seq) in order.iter().enumerate() {
            let n = cache.seq_len(seq).expect("sequence in cache");
            let table = cache.page_table(seq).expect("sequence in cache");
            let o = out_row(out, q.heads, q.batch, d, h, row);
            let mut state = OnlineState { m: &mut m1, n: &mut n1, o, head_dim: d };
            state.reset();
            for (pi, &pid) in table.iter().enumerate() {
                let start = pi * page;
                let len = page.min(n - start);
                let k = cache.page_k_head::<E>(pid, h);
                let v = cache.page_v_head::<E>(pid, h);
                let ks = cache.page_k_head_scale(pid, h);
                let vs = cache.page_v_head_scale(pid, h);
                attend_block_scaled(
                    q.row(h, row),
                    1,
                    d,
                    k,
                    ks,
                    v,
                    vs,
                    len,
                    scale,
                    &mut state,
                    &mut w,
                );
            }
            state.finish();
        }
    }
}
