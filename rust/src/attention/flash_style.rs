//! FlashAttention-style decode attention over the monolithic cache
//! (Dao et al., 2022): tiled KV with online softmax, but organised the way
//! the training-oriented kernel is — fixed square tiles with per-tile
//! partial `(O, m, n)` spilled to scratch and a separate reduction pass.
//!
//! For decode (query length 1) this structure buys nothing and costs extra
//! memory traffic for the partials — which is exactly why the paper's
//! Table 3 shows FlashAttention trailing for inference. We keep the
//! two-pass structure faithfully rather than quietly optimising it away.
//! K/V may be stored at any [`crate::kvcache::KvDtype`]; partials are f32.

use super::online::{attend_block_scaled, OnlineState};
use super::{out_row, Queries};
use crate::kvcache::{Bf16, KvDtype, KvElem, MonolithicKvCache, SeqId, F16, I8};

/// Output layout `[heads, batch, head_dim]`, rows in `order`.
/// `tile` is the KV tile length (FlashAttention uses 64/128-row tiles).
pub fn flash_style_attention(
    cache: &MonolithicKvCache,
    order: &[SeqId],
    q: &Queries,
    tile: usize,
    out: &mut [f32],
) {
    match cache.shape().dtype {
        KvDtype::F32 => flash_impl::<f32>(cache, order, q, tile, out),
        KvDtype::F16 => flash_impl::<F16>(cache, order, q, tile, out),
        KvDtype::Bf16 => flash_impl::<Bf16>(cache, order, q, tile, out),
        KvDtype::Int8 => flash_impl::<I8>(cache, order, q, tile, out),
    }
}

fn flash_impl<E: KvElem>(
    cache: &MonolithicKvCache,
    order: &[SeqId],
    q: &Queries,
    tile: usize,
    out: &mut [f32],
) {
    assert!(tile > 0);
    let shape = cache.shape();
    assert_eq!(q.heads, shape.heads);
    assert_eq!(q.head_dim, shape.head_dim);
    assert_eq!(q.batch, order.len());
    let d = shape.head_dim;
    let scale = q.scale();
    let max_len = order
        .iter()
        .map(|&s| cache.get(s).expect("sequence in cache").len)
        .max()
        .unwrap_or(0);
    let max_tiles = max_len.div_ceil(tile).max(1);
    // Per-tile partial results, spilled like the kernel spills to HBM.
    let mut part_o = vec![0.0f32; max_tiles * d];
    let mut part_m = vec![0.0f32; max_tiles];
    let mut part_n = vec![0.0f32; max_tiles];
    let mut w = vec![0.0f32; tile];
    for h in 0..q.heads {
        for (row, &seq) in order.iter().enumerate() {
            let s = cache.get(seq).expect("sequence in cache");
            let n = s.len;
            let k = s.k_head::<E>(&shape, h);
            let v = s.v_head::<E>(&shape, h);
            let k_scale = s.k_head_scale(&shape, h);
            let v_scale = s.v_head_scale(&shape, h);
            let q_row = q.row(h, row);
            let ntiles = n.div_ceil(tile);
            // Pass 1: independent partials per tile.
            for ti in 0..ntiles {
                let start = ti * tile;
                let len = tile.min(n - start);
                let (mut m1, mut n1) = ([0.0f32; 1], [0.0f32; 1]);
                let o_tile = &mut part_o[ti * d..(ti + 1) * d];
                let mut state = OnlineState { m: &mut m1, n: &mut n1, o: o_tile, head_dim: d };
                state.reset();
                attend_block_scaled(
                    q_row,
                    1,
                    d,
                    &k[start * d..(start + len) * d],
                    k_scale,
                    &v[start * d..(start + len) * d],
                    v_scale,
                    len,
                    scale,
                    &mut state,
                    &mut w,
                );
                // Keep unnormalised (o, m, n) — normalisation happens in the
                // reduction, as in the real kernel.
                part_m[ti] = m1[0];
                part_n[ti] = n1[0];
            }
            // Pass 2: attn_reduce over the spilled partials (Eqn. 2).
            let o = out_row(out, q.heads, q.batch, d, h, row);
            o.fill(0.0);
            let mut m = f32::NEG_INFINITY;
            let mut norm = 0.0f32;
            for ti in 0..ntiles {
                let m_new = m.max(part_m[ti]);
                let x = (part_m[ti] - m_new).exp();
                let y = if m == f32::NEG_INFINITY { 0.0 } else { (m - m_new).exp() };
                for (oi, pi) in o.iter_mut().zip(&part_o[ti * d..(ti + 1) * d]) {
                    *oi = *oi * y + pi * x;
                }
                norm = norm * y + part_n[ti] * x;
                m = m_new;
            }
            let inv = 1.0 / norm;
            for x in o.iter_mut() {
                *x *= inv;
            }
        }
    }
}
