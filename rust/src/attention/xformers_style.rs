//! Memory-efficient attention over the monolithic cache, in the style of
//! xformers' `memory_efficient_attention` (Lefaudeux et al., 2022): the key
//! sequence is processed in blocks with online softmax so no full weight
//! vector is materialised. Still per-sequence and prefix-agnostic; K/V may
//! be stored at any [`crate::kvcache::KvDtype`].

use super::online::{attend_block_scaled, OnlineState};
use super::{out_row, Queries};
use crate::kvcache::{Bf16, KvDtype, KvElem, MonolithicKvCache, SeqId, F16, I8};

/// Output layout `[heads, batch, head_dim]`, rows in `order`.
/// `block` is the KV tile length (xformers uses 32/64 key blocks).
pub fn xformers_style_attention(
    cache: &MonolithicKvCache,
    order: &[SeqId],
    q: &Queries,
    block: usize,
    out: &mut [f32],
) {
    match cache.shape().dtype {
        KvDtype::F32 => xformers_impl::<f32>(cache, order, q, block, out),
        KvDtype::F16 => xformers_impl::<F16>(cache, order, q, block, out),
        KvDtype::Bf16 => xformers_impl::<Bf16>(cache, order, q, block, out),
        KvDtype::Int8 => xformers_impl::<I8>(cache, order, q, block, out),
    }
}

fn xformers_impl<E: KvElem>(
    cache: &MonolithicKvCache,
    order: &[SeqId],
    q: &Queries,
    block: usize,
    out: &mut [f32],
) {
    assert!(block > 0);
    let shape = cache.shape();
    assert_eq!(q.heads, shape.heads);
    assert_eq!(q.head_dim, shape.head_dim);
    assert_eq!(q.batch, order.len());
    let d = shape.head_dim;
    let scale = q.scale();
    let mut w = vec![0.0f32; block];
    let (mut m1, mut n1) = ([0.0f32; 1], [0.0f32; 1]);
    for h in 0..q.heads {
        for (row, &seq) in order.iter().enumerate() {
            let s = cache.get(seq).expect("sequence in cache");
            let n = s.len;
            let k = s.k_head::<E>(&shape, h);
            let v = s.v_head::<E>(&shape, h);
            let k_scale = s.k_head_scale(&shape, h);
            let v_scale = s.v_head_scale(&shape, h);
            let o = out_row(out, q.heads, q.batch, d, h, row);
            let mut state = OnlineState { m: &mut m1, n: &mut n1, o, head_dim: d };
            state.reset();
            let mut t = 0;
            while t < n {
                let len = block.min(n - t);
                attend_block_scaled(
                    q.row(h, row),
                    1,
                    d,
                    &k[t * d..(t + len) * d],
                    k_scale,
                    &v[t * d..(t + len) * d],
                    v_scale,
                    len,
                    scale,
                    &mut state,
                    &mut w,
                );
                t += len;
            }
            state.finish();
        }
    }
}
