//! f64 dense reference attention over the prefix tree — the correctness
//! oracle every production kernel is tested against.

use super::Queries;
use crate::kvcache::{PrefixTree, TreeContext};

/// Dense softmax attention computed in f64 from gathered per-sequence KV.
/// Output layout `[heads, batch, head_dim]`, rows in `ctx.seq_order`.
pub fn oracle_attention(tree: &PrefixTree, ctx: &TreeContext, q: &Queries) -> Vec<f32> {
    let shape = tree.shape();
    assert_eq!(q.heads, shape.heads);
    assert_eq!(q.head_dim, shape.head_dim);
    assert_eq!(q.batch, ctx.seq_order.len());
    let d = shape.head_dim;
    let scale = 1.0 / (d as f64).sqrt();
    let mut out = vec![0.0f32; q.heads * q.batch * d];
    for (row, &seq) in ctx.seq_order.iter().enumerate() {
        let (k, v, tokens) = tree.gather_dense(seq).expect("sequence in context");
        let n = tokens.len();
        for h in 0..q.heads {
            let q_row = q.row(h, row);
            let k_head = &k[h * n * d..(h + 1) * n * d];
            let v_head = &v[h * n * d..(h + 1) * n * d];
            let mut w: Vec<f64> = (0..n)
                .map(|t| {
                    (0..d)
                        .map(|i| q_row[i] as f64 * k_head[t * d + i] as f64)
                        .sum::<f64>()
                        * scale
                })
                .collect();
            let m = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut norm = 0.0;
            for x in w.iter_mut() {
                *x = (*x - m).exp();
                norm += *x;
            }
            let base = (h * q.batch + row) * d;
            for i in 0..d {
                let acc: f64 = (0..n).map(|t| w[t] * v_head[t * d + i] as f64).sum();
                out[base + i] = (acc / norm) as f32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{KvShape, PrefixTree, SeqId};

    #[test]
    fn oracle_uniform_values_returns_value_mean() {
        // With identical K rows, softmax weights are uniform and the output
        // is the mean of V rows.
        let shape = KvShape::new(1, 4, 4);
        let mut tree = PrefixTree::new(shape);
        let mut pos_counter = 0usize;
        tree.insert_sequence(SeqId(0), &[1, 2, 3], &mut |_, _, k: &mut [f32], v: &mut [f32]| {
            k.fill(1.0);
            v.fill(pos_counter as f32);
            pos_counter += 1;
        });
        let ctx = tree.context();
        let qdata = vec![1.0f32; 4];
        let q = Queries::new(&qdata, 1, 1, 4);
        let out = oracle_attention(&tree, &ctx, &q);
        for x in &out {
            assert!((x - 1.0).abs() < 1e-6, "mean of 0,1,2 is 1, got {x}");
        }
    }
}
