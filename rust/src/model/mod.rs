//! Transformer model configurations and per-module cost accounting.
//!
//! [`ModelConfig`] describes a Llama-style decoder. Two presets matter:
//! [`ModelConfig::llama2_7b`] — the paper's evaluation model, used by the
//! analytical Table 1 / end-to-end simulations — and [`ModelConfig::mini`],
//! the small model actually compiled to HLO and served through PJRT by the
//! e2e example (`python/compile/model.py` must agree with it; the artifact
//! manifest cross-checks).
//!
//! FLOPs/MOPs formulas follow the paper's Table 1 conventions:
//! one fused multiply-add = 2 FLOPs, FP16 = 2 bytes per element, decode
//! processes exactly one token per sequence.

pub mod reference;

pub use reference::ReferenceModel;

/// Llama-style decoder configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub n_layers: usize,
    pub d_model: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// SwiGLU inner dimension (Llama uses ~8/3 · d_model rounded).
    pub ffn_dim: usize,
    pub vocab: usize,
}

/// FLOPs and memory operations (bytes) for one kernel invocation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ModuleCost {
    pub flops: f64,
    pub mops: f64,
}

impl ModuleCost {
    pub fn arithmetic_intensity(&self) -> f64 {
        if self.mops == 0.0 {
            0.0
        } else {
            self.flops / self.mops
        }
    }

    pub fn add(&self, other: &ModuleCost) -> ModuleCost {
        ModuleCost { flops: self.flops + other.flops, mops: self.mops + other.mops }
    }

    pub fn scale(&self, k: f64) -> ModuleCost {
        ModuleCost { flops: self.flops * k, mops: self.mops * k }
    }
}

/// FP16 bytes per element, the paper's accounting unit.
pub const DTYPE_BYTES: f64 = 2.0;

impl ModelConfig {
    /// The paper's evaluation model (Llama2 7B: 32×4096, 32 heads, d=128,
    /// SwiGLU 11008, vocab 32000).
    pub fn llama2_7b() -> Self {
        ModelConfig {
            name: "llama2-7b",
            n_layers: 32,
            d_model: 4096,
            heads: 32,
            head_dim: 128,
            ffn_dim: 11008,
            vocab: 32000,
        }
    }

    /// The small model compiled to HLO for the real PJRT decode path.
    /// Must match `python/compile/model.py::MINI`.
    pub fn mini() -> Self {
        ModelConfig {
            name: "mini",
            n_layers: 2,
            d_model: 256,
            heads: 4,
            head_dim: 64,
            ffn_dim: 512,
            vocab: 2048,
        }
    }

    /// Total parameter count (tied embedding).
    pub fn param_count(&self) -> u64 {
        let attn = 4 * self.d_model * self.d_model; // Wq, Wk, Wv, Wo
        let mlp = 3 * self.d_model * self.ffn_dim; // SwiGLU: gate, up, down
        let norms = 2 * self.d_model;
        let per_layer = attn + mlp + norms;
        (self.vocab * self.d_model + self.n_layers * per_layer + self.d_model) as u64
    }

    /// KV-cache bytes per token (all layers, FP16) — the quantity behind the
    /// paper's "4.5 MB per token for GPT-3 175B" intro estimate.
    pub fn kv_bytes_per_token(&self) -> f64 {
        (2 * self.n_layers * self.heads * self.head_dim) as f64 * DTYPE_BYTES
    }

    // ---- Table 1 per-layer decode costs (batch b, context n per seq) ----

    /// QKV projection for one decode step of a batch: `X · W_{q,k,v}`.
    /// (The paper's Table 1 column covers exactly the three projections.)
    pub fn qkv_projection_cost(&self, batch: usize) -> ModuleCost {
        let d = self.d_model as f64;
        let b = batch as f64;
        let flops = 2.0 * b * d * (3.0 * d);
        // Weights dominate; activations are b×d in and 3·b×d out.
        let mops = (3.0 * d * d + b * d + 3.0 * b * d) * DTYPE_BYTES;
        ModuleCost { flops, mops }
    }

    /// Self-attention for one decode step: per sequence `q·Kᵀ` and `P·V`
    /// over `n` context tokens, KV cache streamed from memory.
    pub fn self_attention_cost(&self, batch: usize, context: usize) -> ModuleCost {
        let (h, d) = (self.heads as f64, self.head_dim as f64);
        let (b, n) = (batch as f64, context as f64);
        let flops = b * h * (2.0 * n * d + 2.0 * n * d);
        let kv_bytes = b * 2.0 * n * h * d * DTYPE_BYTES;
        let qo_bytes = 2.0 * b * h * d * DTYPE_BYTES;
        // Materialised attention weights written then read (naive kernel).
        let w_bytes = 2.0 * b * h * n * DTYPE_BYTES;
        ModuleCost { flops, mops: kv_bytes + qo_bytes + w_bytes }
    }

    /// SwiGLU MLP for one decode step.
    pub fn mlp_cost(&self, batch: usize) -> ModuleCost {
        let (d, f) = (self.d_model as f64, self.ffn_dim as f64);
        let b = batch as f64;
        let flops = 2.0 * b * (3.0 * d * f);
        let mops = (3.0 * d * f + b * (2.0 * d + 2.0 * f)) * DTYPE_BYTES;
        ModuleCost { flops, mops }
    }

    /// Output projection (attention `Wo`), not in Table 1 but needed for
    /// end-to-end latency.
    pub fn out_projection_cost(&self, batch: usize) -> ModuleCost {
        let d = self.d_model as f64;
        let b = batch as f64;
        ModuleCost { flops: 2.0 * b * d * d, mops: (d * d + 2.0 * b * d) * DTYPE_BYTES }
    }

    /// Final LM head (vocab projection), once per decode step.
    pub fn lm_head_cost(&self, batch: usize) -> ModuleCost {
        let (d, v) = (self.d_model as f64, self.vocab as f64);
        let b = batch as f64;
        ModuleCost { flops: 2.0 * b * d * v, mops: (d * v + b * (d + v)) * DTYPE_BYTES }
    }

    /// Full prefill cost for a prompt of `n` tokens (one sequence), all
    /// layers: projections + causal attention + MLP. Quadratic attention.
    pub fn prefill_cost(&self, n: usize) -> ModuleCost {
        let nf = n as f64;
        let (h, d, dm, f) = (
            self.heads as f64,
            self.head_dim as f64,
            self.d_model as f64,
            self.ffn_dim as f64,
        );
        // Per layer: QKV+O projections over n tokens, attention n(n+1)/2
        // score rows, MLP over n tokens.
        let proj_flops = 2.0 * nf * dm * (4.0 * dm) + 2.0 * nf * (3.0 * dm * f);
        let attn_flops = h * (4.0 * d) * (nf * (nf + 1.0) / 2.0);
        let flops = self.n_layers as f64 * (proj_flops + attn_flops);
        // Weights once per layer + activations; attention reads its own
        // fresh KV (stays in cache for tiles) — count once.
        let weights = 4.0 * dm * dm + 3.0 * dm * f;
        let act = nf * dm * 6.0 + 2.0 * nf * h * d;
        let mops = self.n_layers as f64 * (weights + act) * DTYPE_BYTES;
        ModuleCost { flops, mops }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llama7b_matches_paper_table1_flops() {
        // Paper Table 1 (b=1, n=2048): QKV 100.66e6, attn 33.57e6,
        // MLP 270.53e6 FLOPs.
        let m = ModelConfig::llama2_7b();
        let qkv = m.qkv_projection_cost(1);
        assert!((qkv.flops / 1e6 - 100.66).abs() < 0.5, "qkv {}", qkv.flops / 1e6);
        let attn = m.self_attention_cost(1, 2048);
        assert!((attn.flops / 1e6 - 33.57).abs() < 0.5, "attn {}", attn.flops / 1e6);
        let mlp = m.mlp_cost(1);
        assert!((mlp.flops / 1e6 - 270.53).abs() < 0.5, "mlp {}", mlp.flops / 1e6);
    }

    #[test]
    fn llama7b_matches_paper_table1_mops() {
        // Paper Table 1 (b=1): QKV 100.70e6, attn 33.85e6, MLP 270.62e6.
        let m = ModelConfig::llama2_7b();
        assert!((m.qkv_projection_cost(1).mops / 1e6 - 100.70).abs() < 0.5);
        assert!((m.self_attention_cost(1, 2048).mops / 1e6 - 33.85).abs() < 0.5);
        assert!((m.mlp_cost(1).mops / 1e6 - 270.62).abs() < 0.5);
    }

    #[test]
    fn llama7b_batch_scaling_matches_paper() {
        // b=32: QKV FLOPs 3221.23e6 but MOPs only 101.71e6 (AI 31.67);
        // attention MOPs scale linearly: 1083.18e6 (AI stays 0.99).
        let m = ModelConfig::llama2_7b();
        let qkv = m.qkv_projection_cost(32);
        assert!((qkv.flops / 1e6 - 3221.23).abs() < 2.0);
        assert!((qkv.mops / 1e6 - 101.71).abs() < 1.0);
        assert!((qkv.arithmetic_intensity() - 31.67).abs() < 0.5);
        let attn = m.self_attention_cost(32, 2048);
        assert!((attn.flops / 1e6 - 1074.27).abs() < 2.0);
        assert!((attn.mops / 1e6 - 1083.18).abs() < 2.0);
        assert!(attn.arithmetic_intensity() < 1.05);
    }

    #[test]
    fn param_counts_are_plausible() {
        let m = ModelConfig::llama2_7b();
        let p = m.param_count() as f64 / 1e9;
        assert!((6.0..7.5).contains(&p), "llama2-7b params {p}B");
        let mini = ModelConfig::mini();
        assert!(mini.param_count() < 5_000_000, "mini stays tiny: {}", mini.param_count());
    }

    #[test]
    fn kv_bytes_per_token() {
        let m = ModelConfig::llama2_7b();
        // 2 * 32 layers * 32 heads * 128 dim * 2 bytes = 512 KiB/token.
        assert_eq!(m.kv_bytes_per_token(), 524288.0);
    }

    #[test]
    fn prefill_cost_grows_superlinearly() {
        let m = ModelConfig::llama2_7b();
        let c1 = m.prefill_cost(1024);
        let c2 = m.prefill_cost(2048);
        assert!(c2.flops > 2.0 * c1.flops, "attention makes prefill superlinear");
    }
}
