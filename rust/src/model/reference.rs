//! Pure-Rust forward pass of the mini model — loads the same
//! `mini_weights.bin` the artifacts were compiled from and recomputes
//! prefill logits/KV independently of XLA. Used by the integration tests
//! to pin the PJRT path: JAX-lowered HLO, the Pallas kernel, and this
//! implementation must all agree on the numbers.

use crate::runtime::manifest::Manifest;

/// One decoder layer's weights (all `[in, out]` row-major as numpy dumps).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub ln1: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub wo: Vec<f32>,
    pub ln2: Vec<f32>,
    pub w_gate: Vec<f32>,
    pub w_up: Vec<f32>,
    pub w_down: Vec<f32>,
}

/// The reference model: config + weights.
pub struct ReferenceModel {
    pub cfg: super::ModelConfig,
    pub embed: Vec<f32>, // [vocab, d_model]
    pub ln_f: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

impl ReferenceModel {
    /// Load from an artifact manifest (weights in flattened-pytree order,
    /// matched by the path names `aot.py` records).
    pub fn load(manifest: &Manifest) -> anyhow::Result<Self> {
        let raw = manifest.load_weights()?;
        let find = |needle: &str| -> anyhow::Result<Vec<f32>> {
            manifest
                .weights
                .iter()
                .position(|w| w.name.contains(needle))
                .map(|i| raw[i].clone())
                .ok_or_else(|| anyhow::anyhow!("weight {needle:?} not in manifest"))
        };
        let cfg = manifest.model;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for li in 0..cfg.n_layers {
            let lw = |key: &str| find(&format!("[{li}]/['{key}']"));
            layers.push(LayerWeights {
                ln1: lw("ln1")?,
                wq: lw("wq")?,
                wk: lw("wk")?,
                wv: lw("wv")?,
                wo: lw("wo")?,
                ln2: lw("ln2")?,
                w_gate: lw("w_gate")?,
                w_up: lw("w_up")?,
                w_down: lw("w_down")?,
            });
        }
        Ok(ReferenceModel { cfg, embed: find("embed")?, ln_f: find("ln_f")?, layers })
    }

    /// Full causal prefill of `tokens` starting at position 0 with no
    /// cached prefix. Returns (last-position logits, K rows `[n][H*d]`,
    /// V rows) — the quantities the PJRT prefill reports.
    pub fn prefill(&self, tokens: &[u32]) -> (Vec<f32>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let cfg = &self.cfg;
        let (n, dm, h, d) = (tokens.len(), cfg.d_model, cfg.heads, cfg.head_dim);
        let mut x = vec![0.0f32; n * dm];
        for (p, &t) in tokens.iter().enumerate() {
            x[p * dm..(p + 1) * dm]
                .copy_from_slice(&self.embed[t as usize * dm..(t as usize + 1) * dm]);
        }
        let mut k_rows = vec![Vec::new(); n];
        let mut v_rows = vec![Vec::new(); n];
        let scale = 1.0 / (d as f32).sqrt();
        for layer in &self.layers {
            let xin = rmsnorm_rows(&x, n, dm, &layer.ln1);
            let mut q = matmul(&xin, n, dm, &layer.wq, h * d);
            let mut k = matmul(&xin, n, dm, &layer.wk, h * d);
            let v = matmul(&xin, n, dm, &layer.wv, h * d);
            for p in 0..n {
                rope_row(&mut q[p * h * d..(p + 1) * h * d], h, d, p);
                rope_row(&mut k[p * h * d..(p + 1) * h * d], h, d, p);
            }
            for p in 0..n {
                k_rows[p].extend_from_slice(&k[p * h * d..(p + 1) * h * d]);
                v_rows[p].extend_from_slice(&v[p * h * d..(p + 1) * h * d]);
            }
            // Causal dense attention.
            let mut attn = vec![0.0f32; n * h * d];
            for p in 0..n {
                for hh in 0..h {
                    let q_row = &q[p * h * d + hh * d..p * h * d + (hh + 1) * d];
                    let mut w: Vec<f32> = (0..=p)
                        .map(|t| {
                            let k_row = &k[t * h * d + hh * d..t * h * d + (hh + 1) * d];
                            q_row.iter().zip(k_row).map(|(a, b)| a * b).sum::<f32>() * scale
                        })
                        .collect();
                    let m = w.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut norm = 0.0;
                    for x in w.iter_mut() {
                        *x = (*x - m).exp();
                        norm += *x;
                    }
                    for t in 0..=p {
                        let e = w[t] / norm;
                        let v_row = &v[t * h * d + hh * d..t * h * d + (hh + 1) * d];
                        for i in 0..d {
                            attn[p * h * d + hh * d + i] += e * v_row[i];
                        }
                    }
                }
            }
            let proj = matmul(&attn, n, h * d, &layer.wo, dm);
            for i in 0..n * dm {
                x[i] += proj[i];
            }
            // SwiGLU MLP.
            let xin2 = rmsnorm_rows(&x, n, dm, &layer.ln2);
            let gate = matmul(&xin2, n, dm, &layer.w_gate, self.cfg.ffn_dim);
            let up = matmul(&xin2, n, dm, &layer.w_up, self.cfg.ffn_dim);
            let act: Vec<f32> =
                gate.iter().zip(&up).map(|(g, u)| (g / (1.0 + (-g).exp())) * u).collect();
            let down = matmul(&act, n, self.cfg.ffn_dim, &layer.w_down, dm);
            for i in 0..n * dm {
                x[i] += down[i];
            }
        }
        let xf = rmsnorm_rows(&x, n, dm, &self.ln_f);
        // Tied LM head: logits = x · embedᵀ, last position only.
        let last = &xf[(n - 1) * dm..n * dm];
        let logits: Vec<f32> = (0..self.cfg.vocab)
            .map(|t| last.iter().zip(&self.embed[t * dm..(t + 1) * dm]).map(|(a, b)| a * b).sum())
            .collect();
        (logits, k_rows, v_rows)
    }
}

fn rmsnorm_rows(x: &[f32], n: usize, d: usize, g: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d];
    for p in 0..n {
        let row = &x[p * d..(p + 1) * d];
        let ms = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + 1e-5).sqrt();
        for i in 0..d {
            out[p * d + i] = row[i] * r * g[i];
        }
    }
    out
}

fn matmul(x: &[f32], n: usize, d_in: usize, w: &[f32], d_out: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), d_in * d_out);
    let mut out = vec![0.0f32; n * d_out];
    for p in 0..n {
        for i in 0..d_in {
            let xv = x[p * d_in + i];
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * d_out..(i + 1) * d_out];
            let orow = &mut out[p * d_out..(p + 1) * d_out];
            for j in 0..d_out {
                orow[j] += xv * wrow[j];
            }
        }
    }
    out
}

/// Rotary embedding matching `model.py::rope` (half-split layout).
fn rope_row(row: &mut [f32], h: usize, d: usize, pos: usize) {
    let half = d / 2;
    for hh in 0..h {
        let base = hh * d;
        for i in 0..half {
            let freq = 1.0 / 10000f32.powf(i as f32 / half as f32);
            let angle = pos as f32 * freq;
            let (sin, cos) = angle.sin_cos();
            let x1 = row[base + i];
            let x2 = row[base + half + i];
            row[base + i] = x1 * cos - x2 * sin;
            row[base + half + i] = x1 * sin + x2 * cos;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rmsnorm_unit_gain() {
        let x = vec![3.0f32, 4.0];
        let out = rmsnorm_rows(&x, 1, 2, &[1.0, 1.0]);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let r = 12.5f32.sqrt();
        assert!((out[0] - 3.0 / r).abs() < 1e-5);
        assert!((out[1] - 4.0 / r).abs() < 1e-5);
    }

    #[test]
    fn matmul_identity() {
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&x, 2, 2, &eye, 2), x);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut row = vec![0.5f32, -0.25, 0.125, 1.0];
        let orig = row.clone();
        rope_row(&mut row, 1, 4, 0);
        for (a, b) in row.iter().zip(&orig) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut row: Vec<f32> = (0..8).map(|i| i as f32 * 0.3 - 1.0).collect();
        let norm0: f32 = row.iter().map(|x| x * x).sum();
        rope_row(&mut row, 2, 4, 17);
        let norm1: f32 = row.iter().map(|x| x * x).sum();
        assert!((norm0 - norm1).abs() < 1e-4);
    }
}
