//! Serving metrics: per-request latency recording, sliding-window
//! throughput, and a text exposition format (Prometheus-style) so the
//! coordinator can be scraped in a real deployment.

pub mod exporter;
pub mod recorder;

pub use exporter::{
    push_gauge, push_histogram, push_histogram_family, push_labeled_gauge, push_labeled_series,
    render_exposition,
};
pub use recorder::{MetricsRecorder, RequestRecord, StepTiming, ThroughputWindow, STEP_PHASES};
