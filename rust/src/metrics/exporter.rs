//! Prometheus-style text exposition of the serving metrics, so a scraper
//! (or a human with `curl`) can watch a live coordinator.

use super::recorder::MetricsRecorder;

/// Append one gauge (HELP + TYPE + sample) to an exposition document.
/// Public so other exporters (the HTTP gateway's `/metrics` endpoint) can
/// extend [`render_exposition`]'s output with their own series.
pub fn push_gauge(out: &mut String, prefix: &str, name: &str, help: &str, value: f64) {
    out.push_str(&format!(
        "# HELP {prefix}_{name} {help}\n# TYPE {prefix}_{name} gauge\n{prefix}_{name} {value}\n"
    ));
}

/// Append one gauge carrying label pairs (e.g. the active KV dtype as
/// `kv_dtype_info{dtype="f16"} 1`, the Prometheus "info" pattern).
pub fn push_labeled_gauge(
    out: &mut String,
    prefix: &str,
    name: &str,
    help: &str,
    labels: &[(&str, &str)],
    value: f64,
) {
    let rendered: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    out.push_str(&format!(
        "# HELP {prefix}_{name} {help}\n# TYPE {prefix}_{name} gauge\n{prefix}_{name}{{{}}} {value}\n",
        rendered.join(",")
    ));
}

/// One labeled sample of a multi-sample series: (label pairs, value).
pub type LabeledSample<'a> = (Vec<(&'a str, String)>, f64);

/// Append one gauge with several labeled samples (one HELP/TYPE header,
/// one sample line per label set) — e.g. the per-tenant serving counters
/// `tenant_admitted_total{tenant="0"} 4`. Emits nothing for an empty row
/// set, so absent series don't clutter the document.
pub fn push_labeled_series(
    out: &mut String,
    prefix: &str,
    name: &str,
    help: &str,
    rows: &[LabeledSample<'_>],
) {
    if rows.is_empty() {
        return;
    }
    out.push_str(&format!("# HELP {prefix}_{name} {help}\n# TYPE {prefix}_{name} gauge\n"));
    for (labels, value) in rows {
        let rendered: Vec<String> =
            labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
        out.push_str(&format!("{prefix}_{name}{{{}}} {value}\n", rendered.join(",")));
    }
}

/// Render the exposition document (text format 0.0.4 subset).
pub fn render_exposition(m: &MetricsRecorder, prefix: &str) -> String {
    let mut out = String::new();
    let mut gauge = |name: &str, help: &str, value: f64| {
        push_gauge(&mut out, prefix, name, help, value);
    };
    gauge("requests_total", "requests completed", m.requests_total as f64);
    gauge("decode_tokens_total", "completion tokens decoded", m.decode_tokens as f64);
    gauge(
        "normalized_latency_ms_mean",
        "mean normalized latency (ms per completion token)",
        m.normalized_latency.mean(),
    );
    gauge(
        "normalized_latency_ms_p99",
        "p99 normalized latency (ms per completion token)",
        m.normalized_latency.percentile(99.0),
    );
    gauge("ttft_ms_mean", "mean time to first token (ms)", m.ttft.mean());
    gauge("queue_delay_ms_mean", "mean admission queueing delay (ms)", m.queue_delay.mean());
    gauge("prefix_hit_rate", "fraction of prompt tokens reused from PAKV", m.prefix_hit_rate());
    gauge(
        "decode_step_us_p50",
        "median decode step latency (us)",
        m.step_latency_us.quantile(0.5),
    );
    gauge(
        "decode_step_us_p99",
        "p99 decode step latency (us)",
        m.step_latency_us.quantile(0.99),
    );
    gauge(
        "context_rebuilds_total",
        "decode steps that refetched the tree context (topology changed)",
        m.context_rebuilds as f64,
    );
    gauge(
        "context_cache_hits_total",
        "decode steps served from the cached tree context",
        m.context_cache_hits as f64,
    );
    gauge(
        "context_cache_hit_rate",
        "fraction of decode steps with an unchanged cached context",
        m.context_hit_rate(),
    );
    gauge(
        "prefill_computed_tokens_total",
        "prompt tokens whose KV was computed at prefill",
        m.prefill_computed as f64,
    );
    gauge(
        "prefill_reused_tokens_total",
        "prompt tokens served from the prefix tree without recomputation",
        m.prefill_reused as f64,
    );
    gauge(
        "requests_cancelled_total",
        "requests cancelled mid-flight (disconnect or abort)",
        m.cancelled as f64,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::recorder::RequestRecord;

    #[test]
    fn exposition_contains_all_series() {
        let mut m = MetricsRecorder::new();
        m.record_request(RequestRecord {
            arrival_s: 0.0,
            admitted_s: 0.1,
            first_token_s: 0.2,
            finished_s: 1.0,
            prompt_tokens: 64,
            completion_tokens: 16,
            reused_prompt_tokens: 32,
        });
        m.record_decode_step(120.0, 2);
        m.context_rebuilds = 3;
        m.context_cache_hits = 9;
        let text = render_exposition(&m, "chunk_attn");
        for series in [
            "chunk_attn_requests_total 1",
            "chunk_attn_decode_tokens_total 2",
            "chunk_attn_prefix_hit_rate 0.5",
            "chunk_attn_normalized_latency_ms_mean",
            "chunk_attn_decode_step_us_p50",
            "chunk_attn_context_rebuilds_total 3",
            "chunk_attn_context_cache_hits_total 9",
            "chunk_attn_context_cache_hit_rate 0.75",
        ] {
            assert!(text.contains(series), "missing {series} in:\n{text}");
        }
        // Every series has HELP and TYPE lines.
        assert_eq!(text.matches("# HELP").count(), text.matches("# TYPE").count());
    }

    #[test]
    fn labeled_series_shares_one_header_across_samples() {
        let mut out = String::new();
        push_labeled_series(
            &mut out,
            "gw",
            "tenant_admitted_total",
            "requests admitted per tenant",
            &[
                (vec![("tenant", "0".to_string())], 4.0),
                (vec![("tenant", "7".to_string())], 1.0),
                (vec![("tenant", "other".to_string())], 9.0),
            ],
        );
        assert_eq!(out.matches("# HELP").count(), 1);
        assert_eq!(out.matches("# TYPE").count(), 1);
        assert!(out.contains("gw_tenant_admitted_total{tenant=\"0\"} 4"));
        assert!(out.contains("gw_tenant_admitted_total{tenant=\"7\"} 1"));
        assert!(out.contains("gw_tenant_admitted_total{tenant=\"other\"} 9"));
        // Empty row sets emit nothing at all.
        let mut empty = String::new();
        push_labeled_series(&mut empty, "gw", "x", "h", &[]);
        assert!(empty.is_empty());
    }
}
