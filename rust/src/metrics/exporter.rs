//! Prometheus-style text exposition of the serving metrics, so a scraper
//! (or a human with `curl`) can watch a live coordinator.

use super::recorder::MetricsRecorder;
use crate::util::stats::LogHistogram;

/// Append one gauge (HELP + TYPE + sample) to an exposition document.
/// Public so other exporters (the HTTP gateway's `/metrics` endpoint) can
/// extend [`render_exposition`]'s output with their own series.
pub fn push_gauge(out: &mut String, prefix: &str, name: &str, help: &str, value: f64) {
    out.push_str(&format!(
        "# HELP {prefix}_{name} {help}\n# TYPE {prefix}_{name} gauge\n{prefix}_{name} {value}\n"
    ));
}

/// Append one gauge carrying label pairs (e.g. the active KV dtype as
/// `kv_dtype_info{dtype="f16"} 1`, the Prometheus "info" pattern).
pub fn push_labeled_gauge(
    out: &mut String,
    prefix: &str,
    name: &str,
    help: &str,
    labels: &[(&str, &str)],
    value: f64,
) {
    let rendered: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    out.push_str(&format!(
        "# HELP {prefix}_{name} {help}\n# TYPE {prefix}_{name} gauge\n{prefix}_{name}{{{}}} {value}\n",
        rendered.join(",")
    ));
}

/// One labeled sample of a multi-sample series: (label pairs, value).
pub type LabeledSample<'a> = (Vec<(&'a str, String)>, f64);

/// Append one gauge with several labeled samples (one HELP/TYPE header,
/// one sample line per label set) — e.g. the per-tenant serving counters
/// `tenant_admitted_total{tenant="0"} 4`. Emits nothing for an empty row
/// set, so absent series don't clutter the document.
pub fn push_labeled_series(
    out: &mut String,
    prefix: &str,
    name: &str,
    help: &str,
    rows: &[LabeledSample<'_>],
) {
    if rows.is_empty() {
        return;
    }
    out.push_str(&format!("# HELP {prefix}_{name} {help}\n# TYPE {prefix}_{name} gauge\n"));
    for (labels, value) in rows {
        let rendered: Vec<String> =
            labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
        out.push_str(&format!("{prefix}_{name}{{{}}} {value}\n", rendered.join(",")));
    }
}

fn render_labels(labels: &[(&str, String)]) -> String {
    labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect::<Vec<_>>().join(",")
}

/// Append the `_bucket`/`_sum`/`_count` sample lines for one histogram
/// (cumulative counts, closed by the mandatory `le="+Inf"` bucket).
fn push_histogram_samples(
    out: &mut String,
    full_name: &str,
    labels: &[(&str, String)],
    h: &LogHistogram,
) {
    let base = render_labels(labels);
    let mut cum = 0u64;
    for (i, &c) in h.counts().iter().enumerate() {
        cum += c;
        let le = if i < h.bounds().len() {
            format!("{}", h.bounds()[i])
        } else {
            "+Inf".to_string()
        };
        let sep = if base.is_empty() { String::new() } else { format!("{base},") };
        out.push_str(&format!("{full_name}_bucket{{{sep}le=\"{le}\"}} {cum}\n"));
    }
    let braces = if base.is_empty() { String::new() } else { format!("{{{base}}}") };
    out.push_str(&format!("{full_name}_sum{braces} {}\n", h.sum()));
    out.push_str(&format!("{full_name}_count{braces} {}\n", h.total()));
}

/// Append one histogram family: a single HELP/TYPE header followed by
/// `_bucket`/`_sum`/`_count` samples per labeled child. Use one call per
/// metric name — the exposition format allows metadata at most once per
/// family, so `step_phase_seconds{phase=...}` children must share a header.
pub fn push_histogram_family(
    out: &mut String,
    prefix: &str,
    name: &str,
    help: &str,
    children: &[(Vec<(&str, String)>, &LogHistogram)],
) {
    if children.is_empty() {
        return;
    }
    let full = format!("{prefix}_{name}");
    out.push_str(&format!("# HELP {full} {help}\n# TYPE {full} histogram\n"));
    for (labels, h) in children {
        push_histogram_samples(out, &full, labels, h);
    }
}

/// Append one unlabeled Prometheus histogram (HELP + TYPE + cumulative
/// `le` buckets + `_sum` + `_count`).
pub fn push_histogram(
    out: &mut String,
    prefix: &str,
    name: &str,
    help: &str,
    h: &LogHistogram,
) {
    push_histogram_family(out, prefix, name, help, &[(Vec::new(), h)]);
}

/// Render the exposition document (text format 0.0.4 subset).
pub fn render_exposition(m: &MetricsRecorder, prefix: &str) -> String {
    let mut out = String::new();
    let mut gauge = |name: &str, help: &str, value: f64| {
        push_gauge(&mut out, prefix, name, help, value);
    };
    gauge("requests_total", "requests completed", m.requests_total as f64);
    gauge("decode_tokens_total", "completion tokens decoded", m.decode_tokens as f64);
    gauge(
        "normalized_latency_ms_mean",
        "mean normalized latency (ms per completion token)",
        m.normalized_latency.mean(),
    );
    gauge(
        "normalized_latency_ms_p99",
        "p99 normalized latency (ms per completion token)",
        m.normalized_latency.percentile(99.0),
    );
    gauge("ttft_ms_mean", "mean time to first token (ms)", m.ttft.mean());
    gauge("queue_delay_ms_mean", "mean admission queueing delay (ms)", m.queue_delay.mean());
    gauge("prefix_hit_rate", "fraction of prompt tokens reused from PAKV", m.prefix_hit_rate());
    gauge(
        "decode_step_us_p50",
        "median decode step latency (us)",
        m.step_latency_us.quantile(0.5),
    );
    gauge(
        "decode_step_us_p99",
        "p99 decode step latency (us)",
        m.step_latency_us.quantile(0.99),
    );
    gauge(
        "context_rebuilds_total",
        "decode steps that refetched the tree context (topology changed)",
        m.context_rebuilds as f64,
    );
    gauge(
        "context_cache_hits_total",
        "decode steps served from the cached tree context",
        m.context_cache_hits as f64,
    );
    gauge(
        "context_cache_hit_rate",
        "fraction of decode steps with an unchanged cached context",
        m.context_hit_rate(),
    );
    gauge(
        "prefill_computed_tokens_total",
        "prompt tokens whose KV was computed at prefill",
        m.prefill_computed as f64,
    );
    gauge(
        "prefill_reused_tokens_total",
        "prompt tokens served from the prefix tree without recomputation",
        m.prefill_reused as f64,
    );
    gauge(
        "requests_cancelled_total",
        "requests cancelled mid-flight (disconnect or abort)",
        m.cancelled as f64,
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::recorder::RequestRecord;

    #[test]
    fn exposition_contains_all_series() {
        let mut m = MetricsRecorder::new();
        m.record_request(RequestRecord {
            arrival_s: 0.0,
            admitted_s: 0.1,
            first_token_s: 0.2,
            finished_s: 1.0,
            prompt_tokens: 64,
            completion_tokens: 16,
            reused_prompt_tokens: 32,
        });
        m.record_decode_step(120.0, 2);
        m.context_rebuilds = 3;
        m.context_cache_hits = 9;
        let text = render_exposition(&m, "chunk_attn");
        for series in [
            "chunk_attn_requests_total 1",
            "chunk_attn_decode_tokens_total 2",
            "chunk_attn_prefix_hit_rate 0.5",
            "chunk_attn_normalized_latency_ms_mean",
            "chunk_attn_decode_step_us_p50",
            "chunk_attn_context_rebuilds_total 3",
            "chunk_attn_context_cache_hits_total 9",
            "chunk_attn_context_cache_hit_rate 0.75",
        ] {
            assert!(text.contains(series), "missing {series} in:\n{text}");
        }
        // Every series has HELP and TYPE lines.
        assert_eq!(text.matches("# HELP").count(), text.matches("# TYPE").count());
    }

    #[test]
    fn histogram_renders_monotone_cumulative_buckets_with_inf() {
        let mut h = LogHistogram::new(0.001, 2.0, 6);
        for x in [0.0005, 0.003, 0.003, 0.02, 5.0] {
            h.record(x);
        }
        let mut out = String::new();
        push_histogram(&mut out, "gw", "ttft_seconds", "time to first token", &h);
        assert_eq!(out.matches("# HELP gw_ttft_seconds ").count(), 1);
        assert!(out.contains("# TYPE gw_ttft_seconds histogram"));
        // Cumulative counts are monotone non-decreasing and end at +Inf.
        let mut prev = 0u64;
        let mut inf_seen = false;
        for line in out.lines().filter(|l| l.contains("_bucket{")) {
            let count: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(count >= prev, "non-monotone bucket line: {line}");
            prev = count;
            if line.contains("le=\"+Inf\"") {
                inf_seen = true;
                assert_eq!(count, h.total(), "+Inf bucket must equal _count");
            }
        }
        assert!(inf_seen, "missing le=\"+Inf\" bucket:\n{out}");
        // _sum/_count agree with the recorder.
        assert!(out.contains(&format!("gw_ttft_seconds_count {}", h.total())));
        let sum_line = out.lines().find(|l| l.starts_with("gw_ttft_seconds_sum ")).unwrap();
        let sum: f64 = sum_line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((sum - h.sum()).abs() < 1e-9);
    }

    #[test]
    fn histogram_family_shares_header_and_labels_every_sample() {
        let mut a = LogHistogram::new(0.001, 2.0, 3);
        let mut b = LogHistogram::new(0.001, 2.0, 3);
        a.record(0.002);
        b.record(0.004);
        b.record(0.004);
        let mut out = String::new();
        push_histogram_family(
            &mut out,
            "gw",
            "step_phase_seconds",
            "per-phase step time",
            &[
                (vec![("phase", "chunk_first".to_string())], &a),
                (vec![("phase", "seq_first".to_string())], &b),
            ],
        );
        assert_eq!(out.matches("# HELP").count(), 1);
        assert_eq!(out.matches("# TYPE").count(), 1);
        assert!(out.contains("gw_step_phase_seconds_bucket{phase=\"chunk_first\",le=\"+Inf\"} 1"));
        assert!(out.contains("gw_step_phase_seconds_bucket{phase=\"seq_first\",le=\"+Inf\"} 2"));
        assert!(out.contains("gw_step_phase_seconds_count{phase=\"chunk_first\"} 1"));
        assert!(out.contains("gw_step_phase_seconds_count{phase=\"seq_first\"} 2"));
        assert!(out.contains("gw_step_phase_seconds_sum{phase=\"seq_first\"} 0.008"));
        // Empty family emits nothing.
        let mut empty = String::new();
        push_histogram_family(&mut empty, "gw", "x", "h", &[]);
        assert!(empty.is_empty());
    }

    #[test]
    fn labeled_series_shares_one_header_across_samples() {
        let mut out = String::new();
        push_labeled_series(
            &mut out,
            "gw",
            "tenant_admitted_total",
            "requests admitted per tenant",
            &[
                (vec![("tenant", "0".to_string())], 4.0),
                (vec![("tenant", "7".to_string())], 1.0),
                (vec![("tenant", "other".to_string())], 9.0),
            ],
        );
        assert_eq!(out.matches("# HELP").count(), 1);
        assert_eq!(out.matches("# TYPE").count(), 1);
        assert!(out.contains("gw_tenant_admitted_total{tenant=\"0\"} 4"));
        assert!(out.contains("gw_tenant_admitted_total{tenant=\"7\"} 1"));
        assert!(out.contains("gw_tenant_admitted_total{tenant=\"other\"} 9"));
        // Empty row sets emit nothing at all.
        let mut empty = String::new();
        push_labeled_series(&mut empty, "gw", "x", "h", &[]);
        assert!(empty.is_empty());
    }
}
