//! Request-level metrics recording for the serving engine.

use crate::util::stats::{LogHistogram, Summary};
use std::collections::VecDeque;

/// Lifecycle timestamps of one request (seconds on a common clock).
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub arrival_s: f64,
    pub admitted_s: f64,
    pub first_token_s: f64,
    pub finished_s: f64,
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    pub reused_prompt_tokens: usize,
}

impl RequestRecord {
    /// Queueing delay before admission.
    pub fn queue_delay_s(&self) -> f64 {
        self.admitted_s - self.arrival_s
    }

    /// Time to first token (TTFT) including queueing.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// End-to-end latency.
    pub fn e2e_s(&self) -> f64 {
        self.finished_s - self.arrival_s
    }

    /// The paper's normalized latency (ms per completion token).
    pub fn normalized_ms_per_tok(&self) -> f64 {
        self.e2e_s() * 1e3 / self.completion_tokens.max(1) as f64
    }
}

/// Sliding-window token throughput (tokens per second over the last `w` s).
#[derive(Debug)]
pub struct ThroughputWindow {
    window_s: f64,
    events: VecDeque<(f64, u64)>, // (time, tokens)
    total_in_window: u64,
}

impl ThroughputWindow {
    pub fn new(window_s: f64) -> Self {
        assert!(window_s > 0.0);
        ThroughputWindow { window_s, events: VecDeque::new(), total_in_window: 0 }
    }

    pub fn record(&mut self, now_s: f64, tokens: u64) {
        self.events.push_back((now_s, tokens));
        self.total_in_window += tokens;
        self.evict(now_s);
    }

    fn evict(&mut self, now_s: f64) {
        while let Some(&(t, n)) = self.events.front() {
            if now_s - t > self.window_s {
                self.events.pop_front();
                self.total_in_window -= n;
            } else {
                break;
            }
        }
    }

    /// Tokens/s over the window ending at `now_s`.
    pub fn rate(&mut self, now_s: f64) -> f64 {
        self.evict(now_s);
        self.total_in_window as f64 / self.window_s
    }
}

/// Aggregates every request record plus decode-step statistics.
#[derive(Debug)]
pub struct MetricsRecorder {
    records: Vec<RequestRecord>,
    /// Cap on retained `records`; `None` keeps all (offline runs, tests).
    /// The gateway bounds this so serving memory is O(window), not
    /// O(total requests); `requests_total` stays a lifetime counter.
    record_limit: Option<usize>,
    pub requests_total: u64,
    pub normalized_latency: Summary,
    pub ttft: Summary,
    pub queue_delay: Summary,
    pub step_latency_us: LogHistogram,
    pub decode_tokens: u64,
    pub prefill_computed: u64,
    pub prefill_reused: u64,
    /// Decode steps that had to (re)fetch the tree context because the
    /// topology generation moved (admission, retirement, chunk boundary).
    pub context_rebuilds: u64,
    /// Decode steps that reused the engine's cached context untouched —
    /// the win of incremental TreeContext caching, observable in e2e runs.
    pub context_cache_hits: u64,
    /// Requests cancelled mid-flight (client disconnect / explicit abort);
    /// their private chunks were returned to the tree pool.
    pub cancelled: u64,
}

impl Default for MetricsRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRecorder {
    pub fn new() -> Self {
        MetricsRecorder {
            records: Vec::new(),
            record_limit: None,
            requests_total: 0,
            normalized_latency: Summary::new(),
            ttft: Summary::new(),
            queue_delay: Summary::new(),
            step_latency_us: LogHistogram::latency_us(),
            decode_tokens: 0,
            prefill_computed: 0,
            prefill_reused: 0,
            context_rebuilds: 0,
            context_cache_hits: 0,
            cancelled: 0,
        }
    }

    /// Fraction of decode steps served from the cached tree context.
    pub fn context_hit_rate(&self) -> f64 {
        let total = self.context_rebuilds + self.context_cache_hits;
        if total == 0 {
            0.0
        } else {
            self.context_cache_hits as f64 / total as f64
        }
    }

    /// Bound retained per-request state: the record list and the latency
    /// summaries' percentile buffers (their streaming moments stay exact).
    /// Counters (`requests_total`, prefill/decode tokens) are lifetime
    /// either way.
    pub fn set_record_limit(&mut self, limit: Option<usize>) {
        self.record_limit = limit;
        self.normalized_latency.set_sample_limit(limit);
        self.ttft.set_sample_limit(limit);
        self.queue_delay.set_sample_limit(limit);
    }

    pub fn record_request(&mut self, r: RequestRecord) {
        self.requests_total += 1;
        self.normalized_latency.add(r.normalized_ms_per_tok());
        self.ttft.add(r.ttft_s() * 1e3);
        self.queue_delay.add(r.queue_delay_s() * 1e3);
        self.prefill_computed += (r.prompt_tokens - r.reused_prompt_tokens) as u64;
        self.prefill_reused += r.reused_prompt_tokens as u64;
        self.records.push(r);
        if let Some(limit) = self.record_limit {
            // Amortized O(1): let the buffer reach 2x before trimming.
            if self.records.len() >= 2 * limit.max(1) {
                let excess = self.records.len() - limit.max(1);
                self.records.drain(..excess);
            }
        }
    }

    pub fn record_decode_step(&mut self, latency_us: f64, batch: usize) {
        self.step_latency_us.record(latency_us);
        self.decode_tokens += batch as u64;
    }

    pub fn requests(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Fraction of prompt tokens served from the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefill_computed + self.prefill_reused;
        if total == 0 {
            0.0
        } else {
            self.prefill_reused as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, finish: f64, completion: usize, reused: usize) -> RequestRecord {
        RequestRecord {
            arrival_s: arrival,
            admitted_s: arrival + 0.1,
            first_token_s: arrival + 0.3,
            finished_s: finish,
            prompt_tokens: 100,
            completion_tokens: completion,
            reused_prompt_tokens: reused,
        }
    }

    #[test]
    fn request_derived_metrics() {
        let r = rec(1.0, 3.0, 20, 50);
        assert!((r.queue_delay_s() - 0.1).abs() < 1e-12);
        assert!((r.ttft_s() - 0.3).abs() < 1e-12);
        assert!((r.e2e_s() - 2.0).abs() < 1e-12);
        assert!((r.normalized_ms_per_tok() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn recorder_aggregates() {
        let mut m = MetricsRecorder::new();
        m.record_request(rec(0.0, 1.0, 10, 60));
        m.record_request(rec(0.0, 2.0, 10, 0));
        m.record_decode_step(500.0, 4);
        assert_eq!(m.requests().len(), 2);
        assert_eq!(m.decode_tokens, 4);
        assert!((m.prefix_hit_rate() - 60.0 / 200.0).abs() < 1e-12);
        assert!((m.normalized_latency.mean() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn record_limit_bounds_retention_not_counters() {
        let mut m = MetricsRecorder::new();
        m.set_record_limit(Some(3));
        for i in 0..100 {
            m.record_request(rec(i as f64, i as f64 + 1.0, 10, 5));
        }
        assert!(m.requests().len() <= 6, "window bounded at 2x the limit");
        assert_eq!(m.requests_total, 100, "lifetime counter unaffected");
        assert_eq!(m.prefill_reused, 500, "cumulative token counters unaffected");
        assert!(m.requests()[0].arrival_s >= 90.0, "oldest dropped first");
        assert_eq!(m.normalized_latency.count(), 100, "summary moments stay lifetime");
        assert!(m.normalized_latency.samples().len() <= 6, "percentile buffer bounded");
    }

    #[test]
    fn throughput_window_slides() {
        let mut w = ThroughputWindow::new(10.0);
        w.record(0.0, 100);
        w.record(5.0, 100);
        assert!((w.rate(5.0) - 20.0).abs() < 1e-12);
        // First event falls out of the window.
        assert!((w.rate(11.0) - 10.0).abs() < 1e-12);
        assert!((w.rate(100.0) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn zero_completion_is_safe() {
        let mut r = rec(0.0, 1.0, 0, 0);
        r.completion_tokens = 0;
        assert!(r.normalized_ms_per_tok().is_finite());
    }
}
