//! Request-level metrics recording for the serving engine.

use crate::util::stats::{LogHistogram, Summary};
use std::collections::VecDeque;

/// Lifecycle timestamps of one request (seconds on a common clock).
#[derive(Debug, Clone, Copy)]
pub struct RequestRecord {
    pub arrival_s: f64,
    pub admitted_s: f64,
    pub first_token_s: f64,
    pub finished_s: f64,
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    pub reused_prompt_tokens: usize,
}

impl RequestRecord {
    /// Queueing delay before admission.
    pub fn queue_delay_s(&self) -> f64 {
        self.admitted_s - self.arrival_s
    }

    /// Time to first token (TTFT) including queueing.
    pub fn ttft_s(&self) -> f64 {
        self.first_token_s - self.arrival_s
    }

    /// End-to-end latency.
    pub fn e2e_s(&self) -> f64 {
        self.finished_s - self.arrival_s
    }

    /// The paper's normalized latency (ms per completion token).
    pub fn normalized_ms_per_tok(&self) -> f64 {
        self.e2e_s() * 1e3 / self.completion_tokens.max(1) as f64
    }
}

/// Phase labels of one engine step, in execution order. Parallel to
/// [`StepTiming::phases`] and the `step_phase_seconds{phase=...}` histogram
/// children on `/metrics`.
pub const STEP_PHASES: [&str; 6] =
    ["plan", "prefill", "chunk_first", "seq_first", "append", "evict"];

/// Wall-clock breakdown of one `Engine::step`, measured always-on with
/// plain monotonic reads (a handful of `Instant::now` calls per step).
/// `chunk_first`/`seq_first` are the TPP kernel's two partition phases,
/// reported by the kernel through `util::trace::record_kernel_phases`;
/// they are zero when the step's runner never entered the TPP kernel.
/// `append` is the decode remainder around the kernel (token append +
/// sampling bookkeeping).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepTiming {
    pub plan_s: f64,
    pub prefill_s: f64,
    pub chunk_first_s: f64,
    pub seq_first_s: f64,
    pub append_s: f64,
    pub evict_s: f64,
    pub total_s: f64,
    /// Sequences decoded this step (0 = prefill/maintenance-only step).
    pub decode_batch: usize,
    /// Prompt slices advanced this step.
    pub prefill_slices: usize,
    /// Requests admitted from the queue this step.
    pub admitted: usize,
    /// Requests that reached completion this step.
    pub finished: usize,
}

impl StepTiming {
    /// `(label, seconds)` per phase, ordered as [`STEP_PHASES`].
    pub fn phases(&self) -> [(&'static str, f64); 6] {
        [
            ("plan", self.plan_s),
            ("prefill", self.prefill_s),
            ("chunk_first", self.chunk_first_s),
            ("seq_first", self.seq_first_s),
            ("append", self.append_s),
            ("evict", self.evict_s),
        ]
    }

    /// Whether the step did any request work (admission, prefill, decode).
    /// Idle maintenance passes are not recorded into the histograms so a
    /// quiet gateway doesn't drown the distributions in no-op samples.
    pub fn did_work(&self) -> bool {
        self.decode_batch > 0 || self.prefill_slices > 0 || self.admitted > 0
    }
}

/// Sliding-window token throughput (tokens per second over the last `w` s).
#[derive(Debug)]
pub struct ThroughputWindow {
    window_s: f64,
    events: VecDeque<(f64, u64)>, // (time, tokens)
    total_in_window: u64,
}

impl ThroughputWindow {
    pub fn new(window_s: f64) -> Self {
        assert!(window_s > 0.0);
        ThroughputWindow { window_s, events: VecDeque::new(), total_in_window: 0 }
    }

    pub fn record(&mut self, now_s: f64, tokens: u64) {
        self.events.push_back((now_s, tokens));
        self.total_in_window += tokens;
        self.evict(now_s);
    }

    fn evict(&mut self, now_s: f64) {
        while let Some(&(t, n)) = self.events.front() {
            if now_s - t > self.window_s {
                self.events.pop_front();
                self.total_in_window -= n;
            } else {
                break;
            }
        }
    }

    /// Tokens/s over the window ending at `now_s`.
    pub fn rate(&mut self, now_s: f64) -> f64 {
        self.evict(now_s);
        self.total_in_window as f64 / self.window_s
    }
}

/// Aggregates every request record plus decode-step statistics.
#[derive(Debug)]
pub struct MetricsRecorder {
    records: Vec<RequestRecord>,
    /// Cap on retained `records`; `None` keeps all (offline runs, tests).
    /// The gateway bounds this so serving memory is O(window), not
    /// O(total requests); `requests_total` stays a lifetime counter.
    record_limit: Option<usize>,
    pub requests_total: u64,
    pub normalized_latency: Summary,
    pub ttft: Summary,
    pub queue_delay: Summary,
    pub step_latency_us: LogHistogram,
    pub decode_tokens: u64,
    pub prefill_computed: u64,
    pub prefill_reused: u64,
    /// Decode steps that had to (re)fetch the tree context because the
    /// topology generation moved (admission, retirement, chunk boundary).
    pub context_rebuilds: u64,
    /// Decode steps that reused the engine's cached context untouched —
    /// the win of incremental TreeContext caching, observable in e2e runs.
    pub context_cache_hits: u64,
    /// Requests cancelled mid-flight (client disconnect / explicit abort);
    /// their private chunks were returned to the tree pool.
    pub cancelled: u64,
    /// Time to first token, seconds (true Prometheus histogram on /metrics).
    pub ttft_seconds: LogHistogram,
    /// Gap between consecutive streamed tokens of one request, seconds.
    pub inter_token_seconds: LogHistogram,
    /// Whole `Engine::step` wall time for steps that did work, seconds.
    pub step_duration_seconds: LogHistogram,
    /// Per-phase step time; index parallel to [`STEP_PHASES`].
    step_phase_seconds: [LogHistogram; STEP_PHASES.len()],
}

impl Default for MetricsRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricsRecorder {
    pub fn new() -> Self {
        MetricsRecorder {
            records: Vec::new(),
            record_limit: None,
            requests_total: 0,
            normalized_latency: Summary::new(),
            ttft: Summary::new(),
            queue_delay: Summary::new(),
            step_latency_us: LogHistogram::latency_us(),
            decode_tokens: 0,
            prefill_computed: 0,
            prefill_reused: 0,
            context_rebuilds: 0,
            context_cache_hits: 0,
            cancelled: 0,
            ttft_seconds: LogHistogram::time_seconds(),
            inter_token_seconds: LogHistogram::time_seconds(),
            step_duration_seconds: LogHistogram::time_seconds(),
            step_phase_seconds: std::array::from_fn(|_| LogHistogram::time_seconds()),
        }
    }

    /// `(phase label, histogram)` pairs for exposition, ordered as
    /// [`STEP_PHASES`].
    pub fn step_phases(&self) -> impl Iterator<Item = (&'static str, &LogHistogram)> {
        STEP_PHASES.iter().copied().zip(self.step_phase_seconds.iter())
    }

    /// Record one step's phase breakdown. Idle maintenance passes
    /// (`!t.did_work()`) are skipped so the histograms describe steps that
    /// actually served requests.
    pub fn record_step_timing(&mut self, t: &StepTiming) {
        if !t.did_work() {
            return;
        }
        self.step_duration_seconds.record(t.total_s);
        for (i, (_, secs)) in t.phases().iter().enumerate() {
            self.step_phase_seconds[i].record(*secs);
        }
    }

    /// Record the gap between two consecutive streamed tokens of a request.
    pub fn record_inter_token(&mut self, dt_s: f64) {
        self.inter_token_seconds.record(dt_s);
    }

    /// Fraction of decode steps served from the cached tree context.
    pub fn context_hit_rate(&self) -> f64 {
        let total = self.context_rebuilds + self.context_cache_hits;
        if total == 0 {
            0.0
        } else {
            self.context_cache_hits as f64 / total as f64
        }
    }

    /// Bound retained per-request state: the record list and the latency
    /// summaries' percentile buffers (their streaming moments stay exact).
    /// Counters (`requests_total`, prefill/decode tokens) are lifetime
    /// either way.
    pub fn set_record_limit(&mut self, limit: Option<usize>) {
        self.record_limit = limit;
        self.normalized_latency.set_sample_limit(limit);
        self.ttft.set_sample_limit(limit);
        self.queue_delay.set_sample_limit(limit);
    }

    pub fn record_request(&mut self, r: RequestRecord) {
        self.requests_total += 1;
        self.normalized_latency.add(r.normalized_ms_per_tok());
        self.ttft.add(r.ttft_s() * 1e3);
        self.ttft_seconds.record(r.ttft_s());
        self.queue_delay.add(r.queue_delay_s() * 1e3);
        self.prefill_computed += (r.prompt_tokens - r.reused_prompt_tokens) as u64;
        self.prefill_reused += r.reused_prompt_tokens as u64;
        self.records.push(r);
        if let Some(limit) = self.record_limit {
            // Amortized O(1): let the buffer reach 2x before trimming.
            if self.records.len() >= 2 * limit.max(1) {
                let excess = self.records.len() - limit.max(1);
                self.records.drain(..excess);
            }
        }
    }

    pub fn record_decode_step(&mut self, latency_us: f64, batch: usize) {
        self.step_latency_us.record(latency_us);
        self.decode_tokens += batch as u64;
    }

    pub fn requests(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Fraction of prompt tokens served from the prefix cache.
    pub fn prefix_hit_rate(&self) -> f64 {
        let total = self.prefill_computed + self.prefill_reused;
        if total == 0 {
            0.0
        } else {
            self.prefill_reused as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(arrival: f64, finish: f64, completion: usize, reused: usize) -> RequestRecord {
        RequestRecord {
            arrival_s: arrival,
            admitted_s: arrival + 0.1,
            first_token_s: arrival + 0.3,
            finished_s: finish,
            prompt_tokens: 100,
            completion_tokens: completion,
            reused_prompt_tokens: reused,
        }
    }

    #[test]
    fn request_derived_metrics() {
        let r = rec(1.0, 3.0, 20, 50);
        assert!((r.queue_delay_s() - 0.1).abs() < 1e-12);
        assert!((r.ttft_s() - 0.3).abs() < 1e-12);
        assert!((r.e2e_s() - 2.0).abs() < 1e-12);
        assert!((r.normalized_ms_per_tok() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn recorder_aggregates() {
        let mut m = MetricsRecorder::new();
        m.record_request(rec(0.0, 1.0, 10, 60));
        m.record_request(rec(0.0, 2.0, 10, 0));
        m.record_decode_step(500.0, 4);
        assert_eq!(m.requests().len(), 2);
        assert_eq!(m.decode_tokens, 4);
        assert!((m.prefix_hit_rate() - 60.0 / 200.0).abs() < 1e-12);
        assert!((m.normalized_latency.mean() - 150.0).abs() < 1e-9);
    }

    #[test]
    fn record_limit_bounds_retention_not_counters() {
        let mut m = MetricsRecorder::new();
        m.set_record_limit(Some(3));
        for i in 0..100 {
            m.record_request(rec(i as f64, i as f64 + 1.0, 10, 5));
        }
        assert!(m.requests().len() <= 6, "window bounded at 2x the limit");
        assert_eq!(m.requests_total, 100, "lifetime counter unaffected");
        assert_eq!(m.prefill_reused, 500, "cumulative token counters unaffected");
        assert!(m.requests()[0].arrival_s >= 90.0, "oldest dropped first");
        assert_eq!(m.normalized_latency.count(), 100, "summary moments stay lifetime");
        assert!(m.normalized_latency.samples().len() <= 6, "percentile buffer bounded");
    }

    #[test]
    fn step_timing_records_phases_and_skips_idle_passes() {
        let mut m = MetricsRecorder::new();
        let idle = StepTiming { total_s: 1e-6, ..Default::default() };
        m.record_step_timing(&idle);
        assert_eq!(m.step_duration_seconds.total(), 0, "idle pass skipped");
        let busy = StepTiming {
            plan_s: 1e-5,
            prefill_s: 2e-4,
            chunk_first_s: 3e-4,
            seq_first_s: 1e-4,
            append_s: 5e-5,
            evict_s: 0.0,
            total_s: 7e-4,
            decode_batch: 4,
            ..Default::default()
        };
        m.record_step_timing(&busy);
        assert_eq!(m.step_duration_seconds.total(), 1);
        for (name, h) in m.step_phases() {
            assert_eq!(h.total(), 1, "phase {name} missed the busy step");
        }
        let phases: Vec<&str> = m.step_phases().map(|(n, _)| n).collect();
        assert_eq!(phases, STEP_PHASES.to_vec());
        let chunk_first = m.step_phases().find(|(n, _)| *n == "chunk_first").unwrap().1;
        assert!((chunk_first.sum() - 3e-4).abs() < 1e-12);
    }

    #[test]
    fn ttft_and_inter_token_histograms_accumulate() {
        let mut m = MetricsRecorder::new();
        m.record_request(rec(0.0, 1.0, 10, 0));
        assert_eq!(m.ttft_seconds.total(), 1);
        assert!((m.ttft_seconds.sum() - 0.3).abs() < 1e-9);
        m.record_inter_token(0.02);
        m.record_inter_token(0.03);
        assert_eq!(m.inter_token_seconds.total(), 2);
        assert!((m.inter_token_seconds.sum() - 0.05).abs() < 1e-12);
    }

    #[test]
    fn throughput_window_slides() {
        let mut w = ThroughputWindow::new(10.0);
        w.record(0.0, 100);
        w.record(5.0, 100);
        assert!((w.rate(5.0) - 20.0).abs() < 1e-12);
        // First event falls out of the window.
        assert!((w.rate(11.0) - 10.0).abs() < 1e-12);
        assert!((w.rate(100.0) - 0.0).abs() < 1e-12);
    }

    #[test]
    fn zero_completion_is_safe() {
        let mut r = rec(0.0, 1.0, 0, 0);
        r.completion_tokens = 0;
        assert!(r.normalized_ms_per_tok().is_finite());
    }
}
