//! `chunk-serve` — the serving-system CLI.
//!
//! Subcommands:
//!   serve      run the engine over an offline synthetic trace (PJRT model
//!              or, with --synthetic, the in-process runner on any build)
//!   gateway    online HTTP/1.1 serving gateway: POST /v1/generate with SSE
//!              token streaming, GET /healthz, GET /metrics; bounded
//!              admission queue (429 backpressure) + disconnect cancellation
//!   bench-http closed-loop multi-tenant load generator over real sockets
//!              (spawns an in-process gateway unless --addr is given)
//!   simulate   virtual-time e2e simulation at Llama2-7B scale (§4.2)
//!   kernel     one microkernel measurement (§4.1)
//!   corpus     print Table-2-style tenant prompt statistics

use chunk_attention::coordinator::engine::testing::{KernelRunner, SyntheticRunner};
use chunk_attention::coordinator::{
    simulate, Engine, KernelBench, MicroConfig, ModelRunner, SchedPolicyKind, SimConfig,
    SystemKind,
};
use chunk_attention::kvcache::KvDtype;
use chunk_attention::model::ModelConfig;
use chunk_attention::perf_model::{AttentionImpl, HardwareModel};
#[cfg(feature = "pjrt")]
use chunk_attention::runtime::PjrtModel;
use chunk_attention::server::{
    render_comparison, render_policy_comparison, render_shard_sweep, render_tiered, run_bench,
    run_chaos_bench, run_policy_comparison, run_prefill_comparison, run_shard_sweep, run_tiered,
    shard_sweep_json, tiered_json, BenchConfig, ChaosBenchConfig, ComparisonConfig, Gateway,
    GatewayConfig, MixedBenchConfig, PolicyComparisonConfig, ShardSweepConfig, TieredBenchConfig,
};
use chunk_attention::util::cli::{Args, Cli};
use chunk_attention::util::failpoint;
use chunk_attention::util::config::Config;
use chunk_attention::util::stats::{fmt_bytes, fmt_us};
use chunk_attention::workload::{Corpus, Tokenizer, Trace, TraceConfig};
use std::time::Duration;

fn parse_or_exit(cli: &Cli, argv: &[String]) -> Args {
    match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

/// Parse a `--kv-dtype` value (`f32` | `f16` | `bf16` | `int8`).
fn parse_kv_dtype(args: &Args) -> anyhow::Result<KvDtype> {
    let s = args.get("kv-dtype");
    KvDtype::parse(s).ok_or_else(|| {
        anyhow::anyhow!("invalid --kv-dtype {s:?}; expected f32, f16, bf16 or int8")
    })
}

/// Parse a `--sched-policy` value (`prefix-greedy` | `drr` | `aging`).
fn parse_sched_policy(args: &Args) -> anyhow::Result<SchedPolicyKind> {
    let s = args.get("sched-policy");
    SchedPolicyKind::parse(s).ok_or_else(|| {
        anyhow::anyhow!("invalid --sched-policy {s:?}; expected prefix-greedy, drr or aging")
    })
}

/// Parse `--tenant-weights 0=4,3=2` into DRR (tenant, weight) pairs.
fn parse_tenant_weights(s: &str) -> anyhow::Result<Vec<(usize, u32)>> {
    let mut weights = Vec::new();
    for pair in s.split(',').filter(|p| !p.is_empty()) {
        let (tenant, weight) = pair
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("bad --tenant-weights entry {pair:?}; want T=W"))?;
        weights.push((
            tenant.trim().parse().map_err(|_| anyhow::anyhow!("bad tenant id {tenant:?}"))?,
            weight.trim().parse().map_err(|_| anyhow::anyhow!("bad weight {weight:?}"))?,
        ));
    }
    Ok(weights)
}

fn main() -> anyhow::Result<()> {
    chunk_attention::util::logger::init();
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match sub.as_str() {
        "serve" => serve(&argv),
        "gateway" => gateway_cmd(&argv),
        "bench-http" => bench_http(&argv),
        "simulate" => simulate_cmd(&argv),
        "kernel" => kernel(&argv),
        "corpus" => corpus(&argv),
        _ => {
            eprintln!(
                "chunk-serve — ChunkAttention serving CLI\n\nSUBCOMMANDS:\n  serve      \
                 offline trace through the engine (--synthetic for the in-process runner)\n  \
                 gateway    streaming HTTP frontend (SSE /v1/generate, /healthz, /metrics)\n  \
                 bench-http closed-loop HTTP load generator (--addr, or spawns a gateway)\n  \
                 simulate   virtual-time Llama2-7B e2e simulation\n  kernel     microkernel \
                 decode measurement\n  corpus     tenant system-prompt statistics\n\nRun a \
                 subcommand with --help for its options.\n"
            );
            Ok(())
        }
    }
}

/// Drive an engine (any runner) through a Poisson offline trace and print
/// the paper-style throughput/reuse summary.
fn run_offline_trace<R: ModelRunner>(
    mut engine: Engine<R>,
    requests: usize,
    tenants: usize,
    sys_tokens: u32,
    completion: usize,
) -> anyhow::Result<()> {
    let trace = Trace::poisson(
        &TraceConfig {
            rps: 50.0,
            n_requests: requests,
            n_tenants: tenants,
            tenant_skew: 0.0,
            query_tokens: 8,
            completion_tokens: completion,
            seed: 11,
        },
        |tenant, rng| {
            let mut p: Vec<u32> = (0..sys_tokens).map(|i| 100 + tenant as u32 * 700 + i).collect();
            p.extend((0..8).map(|_| rng.below(2000) as u32));
            let n = p.len();
            (p, n - 8)
        },
    );
    for r in &trace.requests {
        engine.submit(r.clone());
    }
    let finished = engine.run_to_completion()?;
    let stats = engine.stats();
    println!(
        "served {} requests; decode {:.1} tok/s; prefill reuse {:.0}%",
        finished.len(),
        stats.decoded_tokens as f64 / stats.decode_time_s.max(1e-9),
        100.0 * stats.prefill_tokens_reused as f64
            / (stats.prefill_tokens_computed + stats.prefill_tokens_reused).max(1) as f64
    );
    Ok(())
}

fn serve(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chunk-serve serve", "run the engine over an offline synthetic trace")
        .opt("artifacts", "artifacts", "PJRT artifact directory (unused with --synthetic)")
        .opt("requests", "12", "number of requests")
        .opt("tenants", "2", "tenants (distinct system prompts)")
        .opt("system-tokens", "40", "system prompt tokens per tenant")
        .opt("completion", "12", "completion tokens per request")
        .opt("max-batch", "8", "max decode batch")
        .opt("heads-total", "16", "synthetic runner: total KV heads (n_layers * heads)")
        .opt("head-dim", "32", "synthetic runner: head dimension")
        .opt("chunk", "16", "synthetic runner: KV chunk size (tokens)")
        .opt("kv-dtype", "f32", "KV cache storage dtype: f32|f16|bf16|int8")
        .opt("prefill-chunk-tokens", "0", "chunked prefill slice size in tokens (0 = monolithic)")
        .opt(
            "step-token-budget",
            "0",
            "per-step token budget over prefill slices + decode (0 = unbounded)",
        )
        .opt("sched-policy", "prefix-greedy", "admission policy: prefix-greedy|drr|aging")
        .opt("tenant-weights", "", "DRR per-tenant weights, e.g. 0=4,3=2 (unlisted weigh 1)")
        .opt("config", "", "optional TOML config overriding the flags")
        .flag("synthetic", "use the in-process synthetic runner (works on a default build)");
    let args = parse_or_exit(&cli, argv);
    let kv_dtype = parse_kv_dtype(&args)?;
    let planner_cfg = chunk_attention::coordinator::PlannerConfig {
        policy: parse_sched_policy(&args)?,
        tenant_weights: parse_tenant_weights(args.get("tenant-weights"))?,
        ..chunk_attention::coordinator::PlannerConfig::default()
    };

    let mut requests = args.get_usize("requests");
    let mut max_batch = args.get_usize("max-batch");
    let mut completion = args.get_usize("completion");
    if !args.get("config").is_empty() {
        let cfg = Config::load(std::path::Path::new(args.get("config")))
            .map_err(|e| anyhow::anyhow!(e))?;
        requests = cfg.usize("serve.requests", requests);
        max_batch = cfg.usize("serve.max_batch", max_batch);
        completion = cfg.usize("serve.completion", completion);
    }
    let tenants = args.get_usize("tenants");
    let sys_tokens = args.get_usize("system-tokens") as u32;

    if args.get_flag("synthetic") {
        let runner = SyntheticRunner {
            heads_total: args.get_usize("heads-total"),
            head_dim: args.get_usize("head-dim"),
            vocab: 32000,
        };
        let mut engine = Engine::with_dtype(runner, args.get_usize("chunk"), max_batch, kv_dtype);
        engine.set_chunked_prefill(
            args.get_usize("prefill-chunk-tokens"),
            args.get_usize("step-token-budget"),
        );
        engine.set_planner_config(planner_cfg);
        return run_offline_trace(engine, requests, tenants, sys_tokens, completion);
    }
    // The PJRT path does not wire chunked prefill yet: slices would also
    // need max_prefix capacity validation against the AOT artifacts.
    // Refusing the flags beats silently running monolithic.
    anyhow::ensure!(
        args.get_usize("prefill-chunk-tokens") == 0 && args.get_usize("step-token-budget") == 0,
        "--prefill-chunk-tokens/--step-token-budget are only supported with --synthetic \
         (the PJRT prefill artifact caps the dense prefix a slice may carry)"
    );
    serve_pjrt(
        args.get("artifacts"),
        requests,
        max_batch,
        completion,
        tenants,
        sys_tokens,
        kv_dtype,
        planner_cfg,
    )
}

#[cfg(feature = "pjrt")]
#[allow(clippy::too_many_arguments)]
fn serve_pjrt(
    artifacts: &str,
    requests: usize,
    max_batch: usize,
    completion: usize,
    tenants: usize,
    sys_tokens: u32,
    kv_dtype: KvDtype,
    planner_cfg: chunk_attention::coordinator::PlannerConfig,
) -> anyhow::Result<()> {
    // The PJRT decode path stages chunks into f32 device tensors, so the
    // tree may store at any dtype; rows widen at staging time.
    let model = PjrtModel::load(std::path::Path::new(artifacts))?;
    let chunk_size = model.chunk_size();
    let max_batch = max_batch.min(model.max_batch());
    let mut engine = Engine::with_dtype(model, chunk_size, max_batch, kv_dtype);
    engine.set_planner_config(planner_cfg);
    run_offline_trace(engine, requests, tenants, sys_tokens, completion)
}

#[cfg(not(feature = "pjrt"))]
#[allow(clippy::too_many_arguments)]
fn serve_pjrt(
    _artifacts: &str,
    _requests: usize,
    _max_batch: usize,
    _completion: usize,
    _tenants: usize,
    _sys_tokens: u32,
    _kv_dtype: KvDtype,
    _planner_cfg: chunk_attention::coordinator::PlannerConfig,
) -> anyhow::Result<()> {
    anyhow::bail!(
        "the PJRT-compiled model is not in this build; rerun with --synthetic for the \
         in-process runner, or rebuild with `--features pjrt` (and the real xla crate)"
    )
}

fn gateway_cmd(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new(
        "chunk-serve gateway",
        "online HTTP serving gateway over the prefix-tree engine (SSE streaming)",
    )
    .opt("listen", "127.0.0.1:8080", "bind address (port 0 picks an ephemeral port)")
    .opt(
        "shards",
        "1",
        "engine shards; requests route by consistent-hash prefix affinity, each shard owns \
         its own engine, stepper, and admission queue",
    )
    .opt("max-batch", "16", "max decode batch")
    .opt("queue-cap", "64", "admission queue capacity; submissions beyond it get 429")
    .opt("chunk", "64", "KV chunk size (tokens)")
    .opt("kv-dtype", "f32", "KV cache storage dtype: f32|f16|bf16|int8")
    .opt("heads-total", "16", "synthetic runner: total KV heads")
    .opt("head-dim", "32", "synthetic runner: head dimension")
    .opt("max-new-tokens-cap", "4096", "hard cap on a request's completion budget")
    .opt("decode-interval-us", "0", "pacing between decode steps in microseconds")
    .opt("retain-chunks", "0", "prefix retention budget in chunks (0 = off)")
    .opt(
        "retain-demote-after",
        "0",
        "demote pinned prefixes untouched for this many admissions to int8 side storage \
         (0 = never demote; requires --retain-chunks)",
    )
    .opt(
        "retain-spill-after",
        "0",
        "spill int8-demoted prefixes untouched this long to --kv-spill-dir \
         (0 = keep demoted prefixes in memory)",
    )
    .opt("kv-spill-dir", "", "directory for spilled cold-prefix files (empty = no spilling)")
    .opt("prefill-chunk-tokens", "0", "chunked prefill slice size in tokens (0 = monolithic)")
    .opt(
        "step-token-budget",
        "0",
        "per-step token budget over prefill slices + decode (0 = unbounded)",
    )
    .opt("sched-policy", "prefix-greedy", "admission policy: prefix-greedy|drr|aging")
    .opt("tenant-weights", "", "DRR per-tenant weights, e.g. 0=4,3=2 (unlisted tenants weigh 1)")
    .opt("watchdog-stall-ms", "5000", "stepper watchdog stall threshold in ms (0 = disabled)")
    .opt(
        "trace-out",
        "",
        "write a Chrome trace_event JSON file (load in chrome://tracing or Perfetto) with \
         per-step phase spans and per-request lifecycle events (empty = tracing off)",
    )
    .opt(
        "fail",
        "",
        "arm failpoints, e.g. engine.prefill=1*err(boom)@2,engine.step=5%sleep(10) \
         (also read from the FAILPOINTS env var; empty = all disarmed)",
    )
    .flag("synthetic", "use the in-process synthetic runner (the only gateway runner today)");
    let args = parse_or_exit(&cli, argv);
    let armed = failpoint::configure_list(args.get("fail"))
        .map_err(|e| anyhow::anyhow!("bad --fail spec: {e}"))?;
    if armed > 0 {
        eprintln!("warning: {armed} failpoint site(s) armed via --fail; faults WILL be injected");
    }

    // The gateway decodes token ids with the synthetic sampler but runs
    // the real two-phase-partition attention kernel over the live prefix
    // tree every step, so kernel-phase timings (and the step_phase
    // histograms) reflect actual kernel work. The flag is accepted for
    // symmetry with `serve` and future PJRT support.
    let _ = args.get_flag("synthetic");
    let heads_total = args.get_usize("heads-total");
    let head_dim = args.get_usize("head-dim");
    let chunk = args.get_usize("chunk");
    let max_batch = args.get_usize("max-batch");
    let kv_dtype = parse_kv_dtype(&args)?;
    let trace_out = args.get("trace-out");
    let cfg = GatewayConfig {
        addr: args.get("listen").to_string(),
        shards: args.get_usize("shards"),
        queue_cap: args.get_usize("queue-cap"),
        max_new_tokens_cap: args.get_usize("max-new-tokens-cap"),
        decode_interval: Duration::from_micros(args.get_u64("decode-interval-us")),
        retain_chunks: args.get_usize("retain-chunks"),
        retain_demote_after: args.get_u64("retain-demote-after"),
        retain_spill_after: args.get_u64("retain-spill-after"),
        kv_spill_dir: {
            let d = args.get("kv-spill-dir");
            (!d.is_empty()).then(|| std::path::PathBuf::from(d))
        },
        prefill_chunk_tokens: args.get_usize("prefill-chunk-tokens"),
        step_token_budget: args.get_usize("step-token-budget"),
        sched_policy: parse_sched_policy(&args)?,
        tenant_weights: parse_tenant_weights(args.get("tenant-weights"))?,
        watchdog_stall: Duration::from_millis(args.get_u64("watchdog-stall-ms")),
        trace_path: (!trace_out.is_empty()).then(|| std::path::PathBuf::from(trace_out)),
        ..GatewayConfig::default()
    };
    // Each shard gets its own engine (and KV tree): the factory runs once
    // per shard id.
    let gw = Gateway::start_sharded(
        move |_| {
            Engine::with_dtype(
                KernelRunner::new(heads_total, head_dim, 32000),
                chunk,
                max_batch,
                kv_dtype,
            )
        },
        cfg,
    )?;
    println!("gateway listening on http://{}", gw.addr());
    println!(
        "  POST /v1/generate  JSON {{\"tokens\": [..] | \"text\": \"..\", \"max_new_tokens\": N, \
         \"shared_tokens\": N, \"tenant\": N}} -> text/event-stream"
    );
    println!("  GET  /healthz      liveness probe");
    println!("  GET  /metrics      Prometheus text exposition (0.0.4, with histograms)");
    println!("  GET  /debug/steps  recent engine steps with per-phase timings (JSON)");
    println!("  GET  /debug/tree   prefix-tree residency and sharing snapshot (JSON)");
    println!("  GET  /admin/shards routing table: shard states + hash-ring membership (JSON)");
    println!("  POST /admin/drain?shard=N   stop routing to shard N (in-flight finish)");
    println!("  POST /admin/join?shard=N    return shard N to the routing ring");
    if !trace_out.is_empty() {
        println!("tracing to {trace_out} (Chrome trace_event JSON, rewritten periodically)");
    }
    println!("Ctrl-C to stop.");
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

fn bench_http(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new(
        "chunk-serve bench-http",
        "closed-loop multi-tenant load generator against a serving gateway",
    )
    .opt("addr", "", "gateway address; empty = spawn an in-process synthetic gateway")
    .opt("clients", "8", "concurrent closed-loop clients")
    .opt("requests", "64", "total requests")
    .opt("tenants", "4", "tenants (distinct shared system prompts)")
    .opt("system-tokens", "1024", "system prompt tokens per tenant")
    .opt("query-tokens", "32", "user query tokens per request")
    .opt("completion", "64", "completion tokens per request")
    .opt("seed", "7", "workload seed")
    .opt("shards", "1", "spawned gateway: engine shards (prefix-affinity routing)")
    .opt(
        "shard-sweep",
        "",
        "run the workload once per shard count (e.g. 1,2,4) against freshly spawned \
         gateways and report RPS scaling + per-shard prefix hit rates; pair with \
         --tenants >= max shards and --decode-interval-us ~300 for a stepper-bound sweep",
    )
    .opt("out", "BENCH_shards.json", "shard-sweep mode: JSON results path")
    .opt("tiered-out", "BENCH_tiered.json", "tiered mode: JSON results path")
    .opt("cold-tenants", "24", "tiered mode: cold one-shot prefixes in the tail")
    .opt("retain-chunks", "96", "tiered mode: hot-tree retention budget in chunks (both gateways)")
    .opt("demote-after", "6", "tiered mode: demote pins untouched for this many admissions")
    .opt(
        "spill-after",
        "18",
        "tiered mode: spill int8 pins untouched this many admissions (0 = never spill)",
    )
    .opt("revisits", "8", "tiered mode: cold tenants revisited to trigger promotions")
    .opt("max-batch", "16", "spawned gateway: max decode batch")
    .opt("queue-cap", "64", "spawned gateway: admission queue capacity")
    .opt("chunk", "64", "spawned gateway: KV chunk size")
    .opt("kv-dtype", "f32", "spawned gateway: KV cache storage dtype: f32|f16|bf16")
    .opt("decode-interval-us", "200", "spawned gateway: decode pacing (us)")
    .opt("prefill-chunk-tokens", "0", "spawned gateway: prefill slice tokens (0 = monolithic)")
    .opt("step-token-budget", "0", "spawned gateway: per-step token budget (0 = unbounded)")
    .opt("sched-policy", "prefix-greedy", "spawned gateway: admission policy")
    .opt("tenant-weights", "", "spawned gateway: DRR per-tenant weights, e.g. 0=4,3=2")
    .opt("long-clients", "2", "mixed/skewed mode: closed-loop workers issuing long cold prompts")
    .opt("long-requests", "8", "mixed/skewed mode: total long cold prompts")
    .opt("long-prompt-tokens", "2048", "mixed/skewed mode: tokens per long cold prompt")
    .opt("prefill-us-per-token", "50", "mixed/skewed mode: emulated prefill cost per token (us)")
    .opt(
        "fail",
        "",
        "chaos mode: failpoint profile to arm against the spawned gateway \
         (empty = the default latency + transient-error profile)",
    )
    .opt("watchdog-stall-ms", "500", "chaos mode: spawned gateway's watchdog threshold (ms)")
    .opt(
        "trace-out",
        "",
        "spawned gateway: write a Chrome trace_event JSON file with step-phase spans and \
         request lifecycle events (empty = off; requires a spawned gateway, not --addr)",
    )
    .flag(
        "chaos",
        "spawn a gateway, arm the --fail profile against it, and report availability and \
         error rates under injected faults (plus the gateway's supervision counters)",
    )
    .flag(
        "mixed",
        "run the head-of-line workload (long cold prompts + short shared-prefix requests) \
         against a monolithic and a chunked gateway and print TTFT side by side",
    )
    .flag(
        "skewed",
        "run the skewed-tenant workload (one cold long-prompt tenant vs a hot prefix-sharing \
         storm) under prefix-greedy and aging and print per-tenant TTFT side by side",
    )
    .flag(
        "tiered",
        "run the tiered-retention workload (hot shared prefix + cold one-shot tail) against a \
         tiered (int8 demote + spill) and an untiered gateway at the same hot-tree budget and \
         report resident prompts plus promote/demote latencies",
    );
    let args = parse_or_exit(&cli, argv);
    // Validate the dtype up front even when benchmarking an external
    // gateway (whose dtype is its own; a typo should still fail loudly).
    let kv_dtype = parse_kv_dtype(&args)?;

    if !args.get("shard-sweep").is_empty() {
        anyhow::ensure!(
            args.get("addr").is_empty()
                && !args.get_flag("chaos")
                && !args.get_flag("mixed")
                && !args.get_flag("skewed")
                && !args.get_flag("tiered"),
            "--shard-sweep spawns its own gateways per shard count; drop \
             --addr/--chaos/--mixed/--skewed/--tiered"
        );
        return bench_http_shard_sweep(&args, kv_dtype);
    }
    if args.get_flag("tiered") {
        anyhow::ensure!(
            args.get("addr").is_empty()
                && !args.get_flag("chaos")
                && !args.get_flag("mixed")
                && !args.get_flag("skewed"),
            "--tiered spawns its own tiered and baseline gateways; drop \
             --addr/--chaos/--mixed/--skewed"
        );
        return bench_http_tiered(&args, kv_dtype);
    }
    if args.get_flag("chaos") {
        anyhow::ensure!(
            args.get("addr").is_empty() && !args.get_flag("mixed") && !args.get_flag("skewed"),
            "--chaos spawns its own gateway (failpoints are process-local); drop \
             --addr/--mixed/--skewed"
        );
        return bench_http_chaos(&args, kv_dtype);
    }
    if args.get_flag("skewed") {
        anyhow::ensure!(
            args.get("addr").is_empty() && !args.get_flag("mixed"),
            "--skewed spawns its own per-policy gateways; drop --addr/--mixed"
        );
        return bench_http_skewed(&args, kv_dtype);
    }
    if args.get_flag("mixed") {
        // The comparison needs control of both gateways' prefill configs,
        // so it always spawns its own; refusing --addr beats silently
        // benchmarking something other than the user's server.
        anyhow::ensure!(
            args.get("addr").is_empty(),
            "--mixed spawns its own monolithic and chunked gateways and cannot benchmark an \
             external --addr; drop one of the two flags"
        );
        return bench_http_mixed(&args, kv_dtype);
    }

    let trace_out = args.get("trace-out");
    anyhow::ensure!(
        trace_out.is_empty() || args.get("addr").is_empty(),
        "--trace-out traces the spawned in-process gateway; drop --addr"
    );
    let mut spawned = None;
    let addr = if args.get("addr").is_empty() {
        // Real two-phase-partition kernel over the live tree, synthetic
        // token sampling — so server-side phase histograms and --trace-out
        // spans carry actual kernel timings.
        let chunk = args.get_usize("chunk");
        let max_batch = args.get_usize("max-batch");
        let gw = Gateway::start_sharded(
            move |_| {
                Engine::with_dtype(KernelRunner::new(16, 32, 32000), chunk, max_batch, kv_dtype)
            },
            GatewayConfig {
                addr: "127.0.0.1:0".to_string(),
                shards: args.get_usize("shards"),
                queue_cap: args.get_usize("queue-cap"),
                decode_interval: Duration::from_micros(args.get_u64("decode-interval-us")),
                prefill_chunk_tokens: args.get_usize("prefill-chunk-tokens"),
                step_token_budget: args.get_usize("step-token-budget"),
                sched_policy: parse_sched_policy(&args)?,
                tenant_weights: parse_tenant_weights(args.get("tenant-weights"))?,
                trace_path: (!trace_out.is_empty())
                    .then(|| std::path::PathBuf::from(trace_out)),
                ..GatewayConfig::default()
            },
        )?;
        let addr = gw.addr().to_string();
        println!("spawned in-process gateway on {addr}");
        spawned = Some(gw);
        addr
    } else {
        if kv_dtype != KvDtype::F32 {
            eprintln!(
                "note: --kv-dtype {} only configures a spawned gateway; the gateway at {} \
                 keeps whatever dtype it was started with",
                kv_dtype.label(),
                args.get("addr")
            );
        }
        args.get("addr").to_string()
    };
    let report = run_bench(&BenchConfig {
        addr,
        clients: args.get_usize("clients"),
        requests: args.get_usize("requests"),
        tenants: args.get_usize("tenants"),
        system_tokens: args.get_usize("system-tokens"),
        query_tokens: args.get_usize("query-tokens"),
        max_new_tokens: args.get_usize("completion"),
        seed: args.get_u64("seed"),
        timeout: Duration::from_secs(120),
    })?;
    println!("{}", report.render());
    if let Some(gw) = spawned {
        gw.shutdown()?;
    }
    if !trace_out.is_empty() {
        println!("trace written to {trace_out} (open in chrome://tracing or Perfetto)");
    }
    anyhow::ensure!(report.completed > 0, "no request completed — is the gateway reachable?");
    Ok(())
}

/// `bench-http --shard-sweep 1,2,4`: the closed-loop workload once per
/// shard count against freshly spawned gateways; prints the RPS-scaling
/// table and writes machine-readable results to `--out`.
fn bench_http_shard_sweep(args: &Args, kv_dtype: KvDtype) -> anyhow::Result<()> {
    let shard_counts = args
        .get("shard-sweep")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("bad --shard-sweep entry {s:?}; want e.g. 1,2,4"))
        })
        .collect::<anyhow::Result<Vec<usize>>>()?;
    let cfg = ShardSweepConfig {
        bench: BenchConfig {
            addr: String::new(),
            clients: args.get_usize("clients"),
            requests: args.get_usize("requests"),
            tenants: args.get_usize("tenants"),
            system_tokens: args.get_usize("system-tokens"),
            query_tokens: args.get_usize("query-tokens"),
            max_new_tokens: args.get_usize("completion"),
            seed: args.get_u64("seed"),
            timeout: Duration::from_secs(120),
        },
        shard_counts,
        max_batch: args.get_usize("max-batch"),
        chunk: args.get_usize("chunk"),
        queue_cap: args.get_usize("queue-cap"),
        decode_interval: Duration::from_micros(args.get_u64("decode-interval-us")),
        prefill_us_per_token: args.get_u64("prefill-us-per-token"),
        prefill_chunk_tokens: args.get_usize("prefill-chunk-tokens"),
        step_token_budget: args.get_usize("step-token-budget"),
        kv_dtype,
    };
    let points = run_shard_sweep(&cfg)?;
    println!("{}", render_shard_sweep(&points));
    let out = args.get("out");
    anyhow::ensure!(!out.is_empty(), "--out must name the sweep results file");
    std::fs::write(out, shard_sweep_json(&cfg, &points).pretty() + "\n")?;
    println!("sweep written to {out}");
    anyhow::ensure!(
        points.iter().all(|p| p.report.completed > 0),
        "a sweep point completed no requests — is the workload misconfigured?"
    );
    Ok(())
}

/// `bench-http --chaos`: the closed-loop workload against a freshly
/// spawned gateway with a failpoint profile armed; reports availability,
/// health-probe degradation, and the gateway's supervision counters.
fn bench_http_chaos(args: &Args, kv_dtype: KvDtype) -> anyhow::Result<()> {
    let defaults = ChaosBenchConfig::default();
    let failpoints = match args.get("fail") {
        "" => defaults.failpoints.clone(),
        spec => spec.to_string(),
    };
    let cfg = ChaosBenchConfig {
        bench: BenchConfig {
            addr: String::new(),
            clients: args.get_usize("clients"),
            requests: args.get_usize("requests"),
            tenants: args.get_usize("tenants"),
            system_tokens: args.get_usize("system-tokens"),
            query_tokens: args.get_usize("query-tokens"),
            max_new_tokens: args.get_usize("completion"),
            seed: args.get_u64("seed"),
            timeout: Duration::from_secs(120),
        },
        failpoints,
        max_batch: args.get_usize("max-batch"),
        chunk: args.get_usize("chunk"),
        queue_cap: args.get_usize("queue-cap"),
        decode_interval: Duration::from_micros(args.get_u64("decode-interval-us")),
        prefill_us_per_token: args.get_u64("prefill-us-per-token"),
        prefill_chunk_tokens: match args.get_usize("prefill-chunk-tokens") {
            0 => defaults.prefill_chunk_tokens,
            n => n,
        },
        step_token_budget: match args.get_usize("step-token-budget") {
            0 => defaults.step_token_budget,
            n => n,
        },
        watchdog_stall: Duration::from_millis(args.get_u64("watchdog-stall-ms")),
        kv_dtype,
        trace_path: match args.get("trace-out") {
            "" => None,
            p => Some(std::path::PathBuf::from(p)),
        },
        ..defaults
    };
    let report = run_chaos_bench(&cfg)?;
    println!("{}", report.render());
    if !args.get("trace-out").is_empty() {
        println!(
            "trace written to {} (includes step_retry/step_panic fault events)",
            args.get("trace-out")
        );
    }
    anyhow::ensure!(
        report.bench.completed > 0,
        "no request survived the chaos profile — is it too aggressive?"
    );
    Ok(())
}

/// `bench-http --mixed`: the head-of-line workload against two freshly
/// spawned gateways — monolithic prefill vs chunked — printed side by
/// side. Short requests' TTFT p99 is the number the chunked scheduler
/// exists to fix.
fn bench_http_mixed(args: &Args, kv_dtype: KvDtype) -> anyhow::Result<()> {
    let chunk_tokens = match args.get_usize("prefill-chunk-tokens") {
        0 => 128,
        n => n,
    };
    let budget = match args.get_usize("step-token-budget") {
        0 => chunk_tokens + args.get_usize("max-batch") * 2,
        n => n,
    };
    let cfg = ComparisonConfig {
        mixed: MixedBenchConfig {
            addr: String::new(),
            long_clients: args.get_usize("long-clients"),
            short_clients: args.get_usize("clients"),
            long_requests: args.get_usize("long-requests"),
            short_requests: args.get_usize("requests"),
            long_prompt_tokens: args.get_usize("long-prompt-tokens"),
            shared_prefix_tokens: args.get_usize("system-tokens"),
            short_query_tokens: args.get_usize("query-tokens"),
            max_new_tokens: args.get_usize("completion"),
            timeout: Duration::from_secs(120),
        },
        max_batch: args.get_usize("max-batch"),
        chunk: args.get_usize("chunk"),
        queue_cap: args.get_usize("queue-cap"),
        decode_interval: Duration::from_micros(args.get_u64("decode-interval-us")),
        prefill_us_per_token: args.get_u64("prefill-us-per-token"),
        prefill_chunk_tokens: chunk_tokens,
        step_token_budget: budget,
        kv_dtype,
    };
    let (mono, chunked) = run_prefill_comparison(&cfg)?;
    println!("{}", render_comparison(&cfg, &mono, &chunked));
    anyhow::ensure!(
        mono.short_completed > 0 && chunked.short_completed > 0,
        "no short request completed — is the workload misconfigured?"
    );
    Ok(())
}

/// `bench-http --skewed`: one cold long-prompt tenant vs a hot
/// prefix-sharing storm, once per admission policy (prefix-greedy vs
/// aging). The cold tenant's TTFT p50/p99 is the fairness headline.
fn bench_http_skewed(args: &Args, kv_dtype: KvDtype) -> anyhow::Result<()> {
    let defaults = PolicyComparisonConfig::default();
    let chunk_tokens = match args.get_usize("prefill-chunk-tokens") {
        0 => defaults.prefill_chunk_tokens,
        n => n,
    };
    let budget = match args.get_usize("step-token-budget") {
        0 => chunk_tokens + args.get_usize("max-batch") * 2,
        n => n,
    };
    let cfg = PolicyComparisonConfig {
        mixed: MixedBenchConfig {
            addr: String::new(),
            long_clients: args.get_usize("long-clients").max(1),
            short_clients: args.get_usize("clients"),
            long_requests: args.get_usize("long-requests"),
            short_requests: args.get_usize("requests"),
            long_prompt_tokens: args.get_usize("long-prompt-tokens"),
            shared_prefix_tokens: args.get_usize("system-tokens"),
            short_query_tokens: args.get_usize("query-tokens"),
            max_new_tokens: args.get_usize("completion"),
            timeout: Duration::from_secs(120),
        },
        max_batch: args.get_usize("max-batch"),
        chunk: args.get_usize("chunk"),
        queue_cap: args.get_usize("queue-cap"),
        decode_interval: Duration::from_micros(args.get_u64("decode-interval-us")),
        prefill_us_per_token: args.get_u64("prefill-us-per-token"),
        prefill_chunk_tokens: chunk_tokens,
        step_token_budget: budget,
        kv_dtype,
        ..defaults
    };
    let (greedy, aging) = run_policy_comparison(&cfg)?;
    println!("{}", render_policy_comparison(&cfg, &greedy, &aging));
    anyhow::ensure!(
        greedy.long_completed > 0 && aging.long_completed > 0,
        "no cold-tenant request completed — is the workload misconfigured?"
    );
    Ok(())
}

fn bench_http_tiered(args: &Args, kv_dtype: KvDtype) -> anyhow::Result<()> {
    let cfg = TieredBenchConfig {
        cold_tenants: args.get_usize("cold-tenants"),
        system_tokens: args.get_usize("system-tokens"),
        query_tokens: args.get_usize("query-tokens"),
        max_new_tokens: args.get_usize("completion"),
        revisits: args.get_usize("revisits"),
        seed: args.get_u64("seed"),
        chunk: args.get_usize("chunk"),
        max_batch: args.get_usize("max-batch"),
        queue_cap: args.get_usize("queue-cap"),
        retain_chunks: args.get_usize("retain-chunks"),
        demote_after: args.get_u64("demote-after"),
        spill_after: args.get_u64("spill-after"),
        spill_dir: None,
        kv_dtype,
        decode_interval: Duration::from_micros(args.get_u64("decode-interval-us")),
        timeout: Duration::from_secs(120),
    };
    let report = run_tiered(&cfg)?;
    println!("{}", render_tiered(&report));
    let out = args.get("tiered-out");
    anyhow::ensure!(!out.is_empty(), "--tiered-out must name the results file");
    std::fs::write(out, tiered_json(&cfg, &report).pretty() + "\n")?;
    println!("tiered results written to {out}");
    anyhow::ensure!(
        report.baseline.completed > 0 && report.tiered.completed > 0,
        "a tiered leg completed no requests — is the workload misconfigured?"
    );
    Ok(())
}

fn simulate_cmd(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chunk-serve simulate", "virtual-time 7B-scale e2e simulation")
        .opt("system", "chunkllama", "chunkllama | vllm | tgi")
        .opt("rps", "1.0", "mean requests per second")
        .opt("requests", "100", "requests to simulate")
        .opt("shared", "1024", "shared prompt tokens (n_s)")
        .opt("query", "128", "per-request query tokens")
        .opt("completion", "512", "completion tokens (n_c)")
        .opt("max-batch", "32", "max decode batch")
        .opt(
            "kv-dtype",
            "f16",
            "KV storage dtype the simulator prices cache bytes at: f32|f16|bf16|int8",
        )
        .opt(
            "sched-policy",
            "prefix-greedy",
            "admission policy: prefix-greedy|drr|aging (drr runs unweighted here)",
        )
        .opt("seed", "1234", "trace seed");
    let args = parse_or_exit(&cli, argv);
    let system = match args.get("system") {
        "vllm" => SystemKind::Vllm,
        "tgi" => SystemKind::Tgi,
        _ => SystemKind::ChunkLlama,
    };
    let trace = Trace::poisson_synthetic(
        &TraceConfig {
            rps: args.get_f64("rps"),
            n_requests: args.get_usize("requests"),
            n_tenants: 1,
            tenant_skew: 0.0,
            query_tokens: args.get_usize("query"),
            completion_tokens: args.get_usize("completion"),
            seed: args.get_u64("seed"),
        },
        args.get_usize("shared"),
    );
    let cfg = SimConfig {
        max_batch: args.get_usize("max-batch"),
        kv_dtype: parse_kv_dtype(&args)?,
        policy: parse_sched_policy(&args)?,
        ..SimConfig::new(system)
    };
    let r = simulate(&cfg, &ModelConfig::llama2_7b(), &HardwareModel::a100_80g(), &trace);
    println!("system:            {}", r.system.label());
    println!("sched policy:      {}", cfg.policy.label());
    println!(
        "normalized latency {:.2} ms/tok (p99 {:.2})",
        r.normalized_latency_ms_per_tok, r.p99_normalized_latency
    );
    println!("decode throughput  {:.0} tok/s", r.decode_tps);
    println!("peak KV cache      {} ({})", fmt_bytes(r.peak_kv_bytes), cfg.kv_dtype.label());
    println!("peak batch         {}", r.peak_batch);
    println!(
        "sim duration       {:.1}s (attn {:.1}s, other {:.1}s)",
        r.sim_duration_s, r.attn_time_s, r.other_time_s
    );
    Ok(())
}

fn kernel(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chunk-serve kernel", "one microkernel decode measurement")
        .opt("impl", "chunk", "naive|xformers|flash|paged|paged-shared|chunk")
        .opt("batch", "16", "batch size")
        .opt("heads", "8", "attention heads")
        .opt("np", "1024", "prompt tokens")
        .opt("ns", "1024", "shared prefix tokens")
        .opt("kv-dtype", "f32", "KV cache storage dtype: f32|f16|bf16|int8")
        .opt("steps", "5", "decode steps to time");
    let args = parse_or_exit(&cli, argv);
    let imp = match args.get("impl") {
        "naive" => AttentionImpl::Naive,
        "xformers" => AttentionImpl::Xformers,
        "flash" => AttentionImpl::FlashAttn,
        "paged" => AttentionImpl::PagedAttn,
        "paged-shared" => AttentionImpl::PagedAttnShared,
        _ => AttentionImpl::ChunkAttn,
    };
    let mut cfg =
        MicroConfig::paper(args.get_usize("batch"), args.get_usize("np"), args.get_usize("ns"));
    cfg.heads = args.get_usize("heads");
    cfg.max_new_tokens = args.get_usize("steps") + 1;
    cfg.dtype = parse_kv_dtype(&args)?;
    let mut kb = KernelBench::new(cfg, imp);
    let steps = args.get_usize("steps");
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        kb.decode_step();
        kb.append_round();
    }
    let us = t0.elapsed().as_secs_f64() * 1e6 / steps as f64;
    println!(
        "{}: {} per decode step (b={}, h={}, np={}, ns={}); kv={} ({})",
        imp.label(),
        fmt_us(us),
        cfg.batch,
        cfg.heads,
        cfg.prompt_tokens,
        cfg.shared_tokens,
        fmt_bytes(kb.kv_bytes()),
        cfg.dtype.label()
    );
    Ok(())
}

fn corpus(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chunk-serve corpus", "tenant prompt statistics (Table 2)")
        .opt("tenants", "4", "number of tenants")
        .opt("target-tokens", "1200", "target system prompt tokens")
        .opt("seed", "2024", "seed");
    let args = parse_or_exit(&cli, argv);
    let tok = Tokenizer::default_english();
    let corpus = Corpus::synthesize(
        &tok,
        args.get_usize("tenants"),
        args.get_usize("target-tokens"),
        args.get_u64("seed"),
    );
    for t in &corpus.tenants {
        println!(
            "tenant {} ({:>12}): {} shared tokens",
            t.id,
            t.kind.label(),
            t.system_tokens.len()
        );
    }
    let s = corpus.stats();
    println!("avg {} max {} min {}", s.avg_tokens, s.max_tokens, s.min_tokens);
    Ok(())
}
