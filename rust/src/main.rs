//! `chunk-serve` — the serving-system CLI.
//!
//! Subcommands:
//!   serve      run the real PJRT-backed engine on a synthetic workload
//!   simulate   virtual-time e2e simulation at Llama2-7B scale (§4.2)
//!   kernel     one microkernel measurement (§4.1)
//!   corpus     print Table-2-style tenant prompt statistics

use chunk_attention::coordinator::{simulate, KernelBench, MicroConfig, SimConfig, SystemKind};
use chunk_attention::model::ModelConfig;
use chunk_attention::perf_model::{AttentionImpl, HardwareModel};
#[cfg(feature = "pjrt")]
use chunk_attention::runtime::PjrtModel;
use chunk_attention::util::cli::{Args, Cli};
#[cfg(feature = "pjrt")]
use chunk_attention::util::config::Config;
use chunk_attention::util::stats::{fmt_bytes, fmt_us};
use chunk_attention::workload::{Corpus, Tokenizer, Trace, TraceConfig};

fn parse_or_exit(cli: &Cli, argv: &[String]) -> Args {
    match cli.parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
}

fn main() -> anyhow::Result<()> {
    chunk_attention::util::logger::init();
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    let sub = if argv.is_empty() { "help".to_string() } else { argv.remove(0) };
    match sub.as_str() {
        "serve" => serve(&argv),
        "simulate" => simulate_cmd(&argv),
        "kernel" => kernel(&argv),
        "corpus" => corpus(&argv),
        _ => {
            eprintln!(
                "chunk-serve — ChunkAttention serving CLI\n\nSUBCOMMANDS:\n  serve      \
                 serve a synthetic workload through the PJRT mini model\n  simulate   \
                 virtual-time Llama2-7B e2e simulation\n  kernel     microkernel decode \
                 measurement\n  corpus     tenant system-prompt statistics\n"
            );
            Ok(())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn serve(_argv: &[String]) -> anyhow::Result<()> {
    anyhow::bail!(
        "the `serve` subcommand runs the PJRT-compiled model; rebuild with \
         `--features pjrt` (and the real xla crate) to enable it"
    )
}

#[cfg(feature = "pjrt")]
fn serve(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chunk-serve serve", "serve via the AOT-compiled model")
        .opt("artifacts", "artifacts", "artifact directory")
        .opt("requests", "12", "number of requests")
        .opt("tenants", "2", "tenants (distinct system prompts)")
        .opt("system-tokens", "40", "system prompt tokens per tenant")
        .opt("completion", "12", "completion tokens per request")
        .opt("max-batch", "8", "max decode batch")
        .opt("config", "", "optional TOML config overriding the flags");
    let args = parse_or_exit(&cli, argv);

    let mut requests = args.get_usize("requests");
    let mut max_batch = args.get_usize("max-batch");
    let mut completion = args.get_usize("completion");
    if !args.get("config").is_empty() {
        let cfg = Config::load(std::path::Path::new(args.get("config")))
            .map_err(|e| anyhow::anyhow!(e))?;
        requests = cfg.usize("serve.requests", requests);
        max_batch = cfg.usize("serve.max_batch", max_batch);
        completion = cfg.usize("serve.completion", completion);
    }

    let model = PjrtModel::load(std::path::Path::new(args.get("artifacts")))?;
    let chunk_size = model.chunk_size();
    let max_batch = max_batch.min(model.max_batch());
    let mut engine = chunk_attention::coordinator::Engine::new(model, chunk_size, max_batch);

    let tenants = args.get_usize("tenants");
    let sys_tokens = args.get_usize("system-tokens") as u32;
    let trace = Trace::poisson(
        &TraceConfig {
            rps: 50.0,
            n_requests: requests,
            n_tenants: tenants,
            tenant_skew: 0.0,
            query_tokens: 8,
            completion_tokens: completion,
            seed: 11,
        },
        |tenant, rng| {
            let mut p: Vec<u32> = (0..sys_tokens).map(|i| 100 + tenant as u32 * 700 + i).collect();
            p.extend((0..8).map(|_| rng.below(2000) as u32));
            let n = p.len();
            (p, n - 8)
        },
    );
    for r in &trace.requests {
        engine.submit(r.clone());
    }
    let finished = engine.run_to_completion()?;
    let stats = engine.stats();
    println!(
        "served {} requests; decode {:.1} tok/s; prefill reuse {:.0}%",
        finished.len(),
        stats.decoded_tokens as f64 / stats.decode_time_s.max(1e-9),
        100.0 * stats.prefill_tokens_reused as f64
            / (stats.prefill_tokens_computed + stats.prefill_tokens_reused).max(1) as f64
    );
    Ok(())
}

fn simulate_cmd(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chunk-serve simulate", "virtual-time 7B-scale e2e simulation")
        .opt("system", "chunkllama", "chunkllama | vllm | tgi")
        .opt("rps", "1.0", "mean requests per second")
        .opt("requests", "100", "requests to simulate")
        .opt("shared", "1024", "shared prompt tokens (n_s)")
        .opt("query", "128", "per-request query tokens")
        .opt("completion", "512", "completion tokens (n_c)")
        .opt("max-batch", "32", "max decode batch")
        .opt("seed", "1234", "trace seed");
    let args = parse_or_exit(&cli, argv);
    let system = match args.get("system") {
        "vllm" => SystemKind::Vllm,
        "tgi" => SystemKind::Tgi,
        _ => SystemKind::ChunkLlama,
    };
    let trace = Trace::poisson_synthetic(
        &TraceConfig {
            rps: args.get_f64("rps"),
            n_requests: args.get_usize("requests"),
            n_tenants: 1,
            tenant_skew: 0.0,
            query_tokens: args.get_usize("query"),
            completion_tokens: args.get_usize("completion"),
            seed: args.get_u64("seed"),
        },
        args.get_usize("shared"),
    );
    let cfg = SimConfig { max_batch: args.get_usize("max-batch"), ..SimConfig::new(system) };
    let r = simulate(&cfg, &ModelConfig::llama2_7b(), &HardwareModel::a100_80g(), &trace);
    println!("system:            {}", r.system.label());
    println!(
        "normalized latency {:.2} ms/tok (p99 {:.2})",
        r.normalized_latency_ms_per_tok, r.p99_normalized_latency
    );
    println!("decode throughput  {:.0} tok/s", r.decode_tps);
    println!("peak KV cache      {}", fmt_bytes(r.peak_kv_bytes));
    println!("peak batch         {}", r.peak_batch);
    println!(
        "sim duration       {:.1}s (attn {:.1}s, other {:.1}s)",
        r.sim_duration_s, r.attn_time_s, r.other_time_s
    );
    Ok(())
}

fn kernel(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chunk-serve kernel", "one microkernel decode measurement")
        .opt("impl", "chunk", "naive|xformers|flash|paged|paged-shared|chunk")
        .opt("batch", "16", "batch size")
        .opt("heads", "8", "attention heads")
        .opt("np", "1024", "prompt tokens")
        .opt("ns", "1024", "shared prefix tokens")
        .opt("steps", "5", "decode steps to time");
    let args = parse_or_exit(&cli, argv);
    let imp = match args.get("impl") {
        "naive" => AttentionImpl::Naive,
        "xformers" => AttentionImpl::Xformers,
        "flash" => AttentionImpl::FlashAttn,
        "paged" => AttentionImpl::PagedAttn,
        "paged-shared" => AttentionImpl::PagedAttnShared,
        _ => AttentionImpl::ChunkAttn,
    };
    let mut cfg =
        MicroConfig::paper(args.get_usize("batch"), args.get_usize("np"), args.get_usize("ns"));
    cfg.heads = args.get_usize("heads");
    cfg.max_new_tokens = args.get_usize("steps") + 1;
    let mut kb = KernelBench::new(cfg, imp);
    let steps = args.get_usize("steps");
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        kb.decode_step();
        kb.append_round();
    }
    let us = t0.elapsed().as_secs_f64() * 1e6 / steps as f64;
    println!(
        "{}: {} per decode step (b={}, h={}, np={}, ns={}); kv={}",
        imp.label(),
        fmt_us(us),
        cfg.batch,
        cfg.heads,
        cfg.prompt_tokens,
        cfg.shared_tokens,
        fmt_bytes(kb.kv_bytes_fp16())
    );
    Ok(())
}

fn corpus(argv: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("chunk-serve corpus", "tenant prompt statistics (Table 2)")
        .opt("tenants", "4", "number of tenants")
        .opt("target-tokens", "1200", "target system prompt tokens")
        .opt("seed", "2024", "seed");
    let args = parse_or_exit(&cli, argv);
    let tok = Tokenizer::default_english();
    let corpus = Corpus::synthesize(
        &tok,
        args.get_usize("tenants"),
        args.get_usize("target-tokens"),
        args.get_u64("seed"),
    );
    for t in &corpus.tenants {
        println!(
            "tenant {} ({:>12}): {} shared tokens",
            t.id,
            t.kind.label(),
            t.system_tokens.len()
        );
    }
    let s = corpus.stats();
    println!("avg {} max {} min {}", s.avg_tokens, s.max_tokens, s.min_tokens);
    Ok(())
}
