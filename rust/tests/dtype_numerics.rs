//! Property tests for the dtype-abstracted KV cache: the TPP kernels over
//! f16/bf16/int8-stored trees versus the f64 oracle, across thread counts,
//! with a principled error budget — plus conversion round-trip sweeps that
//! the CI dtype matrix runs under both debug (overflow checks on the
//! bit-twiddling) and `--release`.
//!
//! ## Error budget
//!
//! Two separate comparisons, two separate tolerances:
//!
//! 1. **Kernel vs f64 oracle, same storage** — the oracle gathers the
//!    *stored* (already-quantised) rows widened to f32, so the difference
//!    is pure f32 accumulation + the kernel's `fast_exp` (~2e-7 relative):
//!    tolerance `2e-4 * (1 + |expect|)` independent of dtype.
//! 2. **Reduced-precision tree vs f32 tree, same fill** — quantisation
//!    error. With `|q|,|k|,|v| ≤ 1`: V rounding contributes ≤ `u`, and K
//!    rounding perturbs each logit by ≤ `scale · u · Σ|q_j k_j| ≤ u·√d`,
//!    which moves the softmax-weighted output by ≤ `2·u·√d · max|v|`.
//!    Budget: `3 · (2·√d + 1) · u · (1 + |expect|)` with `u` the dtype's
//!    unit roundoff (2⁻¹¹ for f16, 2⁻⁸ for bf16) and 3× slack for
//!    accumulation. The same shape covers int8 with `u = 1/127`: the
//!    per-head symmetric scale is `max|x| / 127 ≤ 1/127`, so one stored
//!    element is off by at most half a quantization step `scale/2 ≤ u/2`.

use chunk_attention::attention::{oracle_attention, tpp_attention_2d, Queries, Tpp2dScratch};
use chunk_attention::kvcache::{
    dtype::{f16_bits_to_f32, f32_to_f16_bits, f32_to_bf16_bits, bf16_bits_to_f32},
    KvDtype, KvShape, PrefixTree, SeqId,
};
use chunk_attention::util::pbt;
use chunk_attention::util::rng::Pcg64;
use chunk_attention::util::simd::{self, SimdIsa};
use chunk_attention::util::threadpool::ThreadPool;
use std::collections::BTreeMap;

/// One random workload: a shared prefix plus per-sequence suffixes.
#[derive(Debug, Clone)]
struct TreeSpec {
    heads: usize,
    head_dim: usize,
    chunk_size: usize,
    shared: usize,
    suffixes: Vec<usize>,
    seed: u64,
}

fn gen_spec(rng: &mut Pcg64) -> TreeSpec {
    let head_dims = [8usize, 16, 64];
    TreeSpec {
        heads: 1 + rng.below(3) as usize,
        head_dim: head_dims[rng.below(head_dims.len() as u64) as usize],
        chunk_size: [4usize, 8][rng.below(2) as usize],
        shared: rng.below(33) as usize,
        suffixes: (0..2 + rng.below(5)).map(|_| 1 + rng.below(10) as usize).collect(),
        seed: rng.below(1 << 30),
    }
}

fn build_tree(spec: &TreeSpec, dtype: KvDtype) -> PrefixTree {
    let shape = KvShape::new(spec.heads, spec.head_dim, spec.chunk_size).with_dtype(dtype);
    let mut tree = PrefixTree::new(shape);
    let seed = spec.seed;
    for (i, &suffix) in spec.suffixes.iter().enumerate() {
        let mut prompt: Vec<u32> = (0..spec.shared as u32).collect();
        prompt.extend((0..suffix as u32).map(|j| 10_000 + i as u32 * 100 + j));
        tree.insert_sequence(SeqId(i as u64), &prompt, &mut |pos, token, k, v| {
            let mut r = Pcg64::new(seed ^ token as u64, pos as u64);
            r.fill_uniform_f32(k, -1.0, 1.0);
            r.fill_uniform_f32(v, -1.0, 1.0);
        });
    }
    tree
}

fn queries_for(spec: &TreeSpec, b: usize) -> Vec<f32> {
    let mut rng = Pcg64::new(spec.seed.wrapping_add(77), 1);
    let mut q = vec![0.0f32; spec.heads * b * spec.head_dim];
    rng.fill_uniform_f32(&mut q, -1.0, 1.0);
    q
}

fn run_2d(tree: &mut PrefixTree, spec: &TreeSpec, workers: usize) -> (Vec<f32>, Vec<f32>) {
    let ctx = tree.context();
    let b = ctx.seq_order.len();
    let qdata = queries_for(spec, b);
    let q = Queries::new(&qdata, spec.heads, b, spec.head_dim);
    let expect = oracle_attention(tree, &ctx, &q);
    let pool = ThreadPool::new(workers);
    let mut scratch = Tpp2dScratch::new();
    let mut out = vec![0.0f32; expect.len()];
    tpp_attention_2d(tree, &ctx, &q, &pool, &mut scratch, &mut out);
    (out, expect)
}

/// Kernel-vs-oracle across every (thread count × dtype) grid point, with
/// bit-identity across thread counts per (case, dtype).
#[test]
fn tpp_2d_matches_oracle_across_threads_and_dtypes() {
    let grid: Vec<(usize, KvDtype)> = [1usize, 2, 8]
        .iter()
        .flat_map(|&w| KvDtype::ALL.iter().map(move |&d| (w, d)))
        .collect();
    // First output per (case, dtype): later thread counts must reproduce
    // it bit-for-bit (the 2D schedule's determinism guarantee).
    let mut reference: BTreeMap<(usize, &'static str), Vec<f32>> = BTreeMap::new();
    pbt::check_grid(
        "tpp2d-oracle-dtype-grid",
        0xD17E,
        16,
        &grid,
        gen_spec,
        |case, spec, (workers, dtype)| {
            let mut tree = build_tree(spec, dtype);
            let (out, expect) = run_2d(&mut tree, spec, workers);
            for (i, (&got, &want)) in out.iter().zip(&expect).enumerate() {
                let tol = 2e-4 * (1.0 + want.abs());
                if (got - want).abs() > tol {
                    return Err(format!(
                        "{dtype:?} workers={workers} idx {i}: kernel {got} vs oracle {want}"
                    ));
                }
            }
            match reference.entry((case, dtype.label())) {
                std::collections::btree_map::Entry::Vacant(slot) => {
                    slot.insert(out);
                }
                std::collections::btree_map::Entry::Occupied(slot) => {
                    if slot.get() != &out {
                        return Err(format!(
                            "{dtype:?}: workers={workers} diverged bitwise from first run"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

/// Scalar is the bit-identity oracle: every accelerated ISA path available
/// on this host must reproduce the scalar kernel output *bit for bit* at
/// every storage dtype and thread count, on workload-shaped trees (shared
/// prefix + per-sequence suffixes). The oracle-tolerance tests above bound
/// the error; this one asserts the exact scalar↔SIMD contract from
/// DESIGN.md "The SIMD dispatch seam" — the vector bodies replicate the
/// scalar reduction geometry, so there is nothing to tolerate.
#[test]
fn every_isa_path_matches_scalar_bit_for_bit() {
    // Under the CI scalar leg (`PALLAS_SIMD=scalar`) the grid collapses to
    // scalar-only so the leg never executes a vector body.
    let isas: Vec<SimdIsa> = if simd::env_request() == "scalar" {
        vec![SimdIsa::Scalar]
    } else {
        simd::available()
    };
    pbt::check("isa-bit-identity", 0x51D3, 12, gen_spec, |spec| {
        for &dtype in &KvDtype::ALL {
            for workers in [1usize, 4] {
                let mut tree = build_tree(spec, dtype);
                simd::force(Some(SimdIsa::Scalar));
                let (base, _) = run_2d(&mut tree, spec, workers);
                for &isa in &isas {
                    simd::force(Some(isa));
                    let (out, _) = run_2d(&mut tree, spec, workers);
                    if out != base {
                        return Err(format!(
                            "{dtype:?} workers={workers} isa {}: output differs bitwise \
                             from the scalar oracle",
                            isa.label()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
    simd::force(None);
}

/// Half-precision storage vs f32 storage on the same workload: bounded by
/// the dtype's unit roundoff (see the module docs for the derivation), and
/// structurally identical (dtype never changes tree topology).
#[test]
fn half_precision_tree_tracks_f32_tree_within_unit_roundoff_budget() {
    pbt::check_grid(
        "half-vs-f32-budget",
        0xBEEF,
        24,
        &[KvDtype::F16, KvDtype::Bf16],
        gen_spec,
        |_case, spec, dtype| {
            let mut f32_tree = build_tree(spec, KvDtype::F32);
            let mut half_tree = build_tree(spec, dtype);
            if half_tree.pool().in_use() != f32_tree.pool().in_use() {
                return Err("storage dtype changed the chunk count".into());
            }
            if half_tree.pool().in_use_bytes() * 2 != f32_tree.pool().in_use_bytes() {
                return Err(format!(
                    "half-precision bytes {} are not half of f32 bytes {}",
                    half_tree.pool().in_use_bytes(),
                    f32_tree.pool().in_use_bytes()
                ));
            }
            let (f32_out, _) = run_2d(&mut f32_tree, spec, 2);
            let (half_out, _) = run_2d(&mut half_tree, spec, 2);
            let u = dtype.unit_roundoff();
            let budget = 3.0 * (2.0 * (spec.head_dim as f32).sqrt() + 1.0) * u;
            for (i, (&got, &want)) in half_out.iter().zip(&f32_out).enumerate() {
                let tol = budget * (1.0 + want.abs());
                if (got - want).abs() > tol {
                    return Err(format!(
                        "{dtype:?} idx {i}: {got} vs f32 {want} exceeds budget {tol}"
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Int8 storage vs f32 storage: the same budget shape as the half-precision
/// test with `u = 1/127` — one quantization step of the per-head symmetric
/// scale (module docs derive why a stored element is off by ≤ `u/2`). Byte
/// accounting must come out to a quarter of f32 *plus* the per-head scale
/// words each int8 chunk carries.
#[test]
fn int8_tree_tracks_f32_tree_within_quant_step_budget() {
    pbt::check("int8-vs-f32-budget", 0x18A7, 24, gen_spec, |spec| {
        let mut f32_tree = build_tree(spec, KvDtype::F32);
        let mut int8_tree = build_tree(spec, KvDtype::Int8);
        if int8_tree.pool().in_use() != f32_tree.pool().in_use() {
            return Err("storage dtype changed the chunk count".into());
        }
        let scale_bytes = int8_tree.pool().in_use() * 2 * spec.heads * 4;
        if (int8_tree.pool().in_use_bytes() - scale_bytes) * 4 != f32_tree.pool().in_use_bytes() {
            return Err(format!(
                "int8 bytes {} minus {scale_bytes} scale bytes are not a quarter of f32 bytes {}",
                int8_tree.pool().in_use_bytes(),
                f32_tree.pool().in_use_bytes()
            ));
        }
        let (f32_out, _) = run_2d(&mut f32_tree, spec, 2);
        let (int8_out, _) = run_2d(&mut int8_tree, spec, 2);
        let u = KvDtype::Int8.unit_roundoff();
        let budget = 3.0 * (2.0 * (spec.head_dim as f32).sqrt() + 1.0) * u;
        for (i, (&got, &want)) in int8_out.iter().zip(&f32_out).enumerate() {
            let tol = budget * (1.0 + want.abs());
            if (got - want).abs() > tol {
                return Err(format!("idx {i}: {got} vs f32 {want} exceeds budget {tol}"));
            }
        }
        Ok(())
    });
}

/// Decode-append keeps the dtype seam consistent: growing trees at every
/// dtype keep matching the oracle step after step.
#[test]
fn decode_appends_stay_within_budget_at_every_dtype() {
    pbt::check_grid(
        "append-dtype-grid",
        0xA99E,
        8,
        &KvDtype::ALL,
        gen_spec,
        |_case, spec, dtype| {
            let mut tree = build_tree(spec, dtype);
            for round in 0..3u32 {
                let (out, expect) = run_2d(&mut tree, spec, 2);
                for (i, (&got, &want)) in out.iter().zip(&expect).enumerate() {
                    if (got - want).abs() > 2e-4 * (1.0 + want.abs()) {
                        return Err(format!("{dtype:?} round {round} idx {i}: {got} vs {want}"));
                    }
                }
                let row = vec![0.25f32; spec.heads * spec.head_dim];
                let seqs: Vec<SeqId> = (0..spec.suffixes.len() as u64).map(SeqId).collect();
                for s in seqs {
                    tree.append_token(s, 50_000 + round, &row, &row);
                }
                tree.check_invariants().map_err(|e| format!("{dtype:?}: {e}"))?;
            }
            Ok(())
        },
    );
}

/// Exhaustive f16 round trip + RNE tie cases, also exercised by the CI
/// dtype matrix in debug mode where integer overflow checks are on.
#[test]
fn conversion_round_trip_sweeps() {
    for h in 0u16..=u16::MAX {
        let f = f16_bits_to_f32(h);
        if f.is_nan() {
            assert!(f16_bits_to_f32(f32_to_f16_bits(f)).is_nan());
            continue;
        }
        assert_eq!(f32_to_f16_bits(f), h, "f16 bits {h:#06x}");
    }
    for b in 0u16..=u16::MAX {
        let f = bf16_bits_to_f32(b);
        if f.is_nan() {
            assert!(bf16_bits_to_f32(f32_to_bf16_bits(f)).is_nan());
            continue;
        }
        assert_eq!(f32_to_bf16_bits(f), b, "bf16 bits {b:#06x}");
    }
    // RNE ties and range edges (reference values cross-checked against
    // IEEE-754 binary16 semantics).
    assert_eq!(f32_to_f16_bits(1.0 + 1.0 / 2048.0), 0x3c00, "tie rounds to even");
    assert_eq!(f32_to_f16_bits(1.0 + 3.0 / 4096.0), 0x3c01, "above tie rounds up");
    assert_eq!(f32_to_f16_bits(65519.9), 0x7bff, "below overflow tie stays finite");
    assert_eq!(f32_to_f16_bits(65520.0), 0x7c00, "overflow tie rounds to +inf");
    assert_eq!(f32_to_f16_bits(2.0f32.powi(-25)), 0x0000, "subnormal tie to even");
    assert_eq!(f32_to_f16_bits(f32::from_bits(0x33000001)), 0x0001, "just above tie");
    assert!(f16_bits_to_f32(0x0001) == 2.0f32.powi(-24), "smallest subnormal exact");
    assert_eq!(f32_to_bf16_bits(f32::INFINITY), 0x7f80);
    assert_eq!(f32_to_bf16_bits(f32::NEG_INFINITY), 0xff80);
    assert!(bf16_bits_to_f32(f32_to_bf16_bits(f32::NAN)).is_nan());
}
