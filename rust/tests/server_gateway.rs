//! Socket-level e2e tests of the HTTP serving gateway: real TCP clients
//! against a gateway running the synthetic runner — SSE streaming, shared-
//! prefix reuse observed via /metrics, 429 backpressure, disconnect
//! cancellation, and graceful shutdown.
//!
//! Every test runs under a hard watchdog so a hung accept loop or a
//! deadlocked stepper fails the test quickly instead of stalling CI.

use chunk_attention::coordinator::engine::testing::SyntheticRunner;
use chunk_attention::coordinator::{Engine, SchedPolicyKind};
use chunk_attention::kvcache::KvDtype;
use chunk_attention::server::client::{self, StreamEvent};
use chunk_attention::server::{gauge_value, labeled_gauge_value, Gateway, GatewayConfig};
use chunk_attention::util::json::Json;
use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

/// Run `f` on a worker thread; panic (failing the test fast) if it does
/// not finish within `secs`. The hard per-test timeout for CI.
fn with_watchdog<T: Send + 'static>(
    secs: u64,
    name: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    let worker = thread::spawn(move || {
        let result = f();
        let _ = tx.send(());
        result
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("test {name} exceeded its {secs}s watchdog (hung gateway?)")
        }
        // Ok: body finished; Disconnected: body panicked — join either way
        // so the original panic propagates with its message.
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => match worker.join() {
            Ok(result) => result,
            Err(panic) => std::panic::resume_unwind(panic),
        },
    }
}

/// Storage dtype for the suite's engines: the `KV_DTYPE` env (CI runs an
/// `int8` socket leg, also combined with `PALLAS_SIMD=scalar`) or f32.
fn suite_kv_dtype() -> KvDtype {
    match std::env::var("KV_DTYPE") {
        Ok(v) => KvDtype::parse(&v).expect("KV_DTYPE must be f32, f16, bf16 or int8"),
        Err(_) => KvDtype::F32,
    }
}

fn engine(chunk: usize, max_batch: usize) -> Engine<SyntheticRunner> {
    Engine::with_dtype(
        SyntheticRunner { heads_total: 2, head_dim: 8, vocab: 32000 },
        chunk,
        max_batch,
        suite_kv_dtype(),
    )
}

/// Base gateway config for the suite. CI runs the whole socket suite a
/// second time with `CHUNKED_PREFILL_BUDGET` set, a third time with
/// `SCHED_POLICY=drr`, a fourth time with `SHARDS=2`, and a fifth time
/// with `KV_DTYPE=int8` (see .github/workflows/ci.yml), so every e2e
/// scenario — streaming, backpressure, cancellation, shutdown, bench —
/// also exercises the interleaved chunked-prefill path, the non-default
/// planner policies, the prefix-affinity router, and quantized KV storage
/// under the same watchdogs.
fn base_cfg() -> GatewayConfig {
    let mut cfg = GatewayConfig::default();
    if let Ok(v) = std::env::var("CHUNKED_PREFILL_BUDGET") {
        let budget: usize =
            v.parse().expect("CHUNKED_PREFILL_BUDGET must be a token count");
        cfg.step_token_budget = budget;
        cfg.prefill_chunk_tokens = (budget / 4).max(16);
    }
    if let Ok(v) = std::env::var("SCHED_POLICY") {
        cfg.sched_policy = SchedPolicyKind::parse(&v)
            .expect("SCHED_POLICY must be prefix-greedy, drr or aging");
    }
    if let Ok(v) = std::env::var("SHARDS") {
        cfg.shards = v.parse().expect("SHARDS must be a shard count");
    }
    cfg
}

/// Spawn a gateway honoring `cfg.shards`: every shard gets its own
/// synthetic engine built from the same (chunk, max_batch) recipe, so the
/// suite's admission and reuse scenarios hold per shard.
fn start_gw(chunk: usize, max_batch: usize, cfg: GatewayConfig) -> Gateway {
    Gateway::start_sharded(move |_| engine(chunk, max_batch), cfg).unwrap()
}

fn token_body(tokens: &[u32], shared: usize, max_new: usize) -> Json {
    let mut body = Json::obj();
    body.set("tokens", Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()));
    body.set("shared_tokens", shared).set("max_new_tokens", max_new);
    body
}

fn scrape(addr: &str) -> String {
    let resp = client::get(addr, "/metrics", Duration::from_secs(10)).expect("scrape /metrics");
    assert_eq!(resp.status, 200);
    resp.body
}

#[test]
fn concurrent_clients_share_a_1024_token_prefix_and_stream_incrementally() {
    with_watchdog(60, "shared_prefix_streaming", || {
        let cfg = GatewayConfig {
            decode_interval: Duration::from_micros(500),
            ..base_cfg()
        };
        let gw = start_gw(64, 8, cfg);
        let addr = gw.addr().to_string();
        let system_prompt: Vec<u32> = (0..1024).collect();

        let mut clients = Vec::new();
        for c in 0..4u32 {
            let addr = addr.clone();
            let mut prompt = system_prompt.clone();
            prompt.extend([5000 + c, 6000 + c]);
            clients.push(thread::spawn(move || {
                let body = token_body(&prompt, 1024, 8);
                let t0 = Instant::now();
                let mut stream =
                    client::generate(&addr, &body, Duration::from_secs(30)).unwrap();
                assert_eq!(stream.status(), 200, "{}", stream.error_body);
                let mut tokens = 0usize;
                let mut first_token_at = None;
                let mut done_at = None;
                while let Some(ev) = stream.next_event().unwrap() {
                    match ev {
                        StreamEvent::Token { index, .. } => {
                            assert_eq!(index, tokens, "tokens arrive in order");
                            if first_token_at.is_none() {
                                first_token_at = Some(t0.elapsed());
                            }
                            tokens += 1;
                        }
                        StreamEvent::Done { completion_tokens } => {
                            assert_eq!(completion_tokens, 8);
                            done_at = Some(t0.elapsed());
                            break;
                        }
                        other => panic!("unexpected terminal event: {other:?}"),
                    }
                }
                assert_eq!(tokens, 8, "all completion tokens streamed");
                let (first, done) = (first_token_at.unwrap(), done_at.unwrap());
                assert!(
                    first < done,
                    "first token ({first:?}) must arrive before stream completion ({done:?})"
                );
            }));
        }
        for c in clients {
            c.join().unwrap();
        }

        // Server-side proof of prefix reuse: the three later requests each
        // skipped the 1024-token matched prefix at prefill.
        let metrics = scrape(&addr);
        let reused = gauge_value(&metrics, "prefill_reused_tokens_total").unwrap();
        assert!(reused >= 3.0 * 1024.0, "prefill reused only {reused} tokens:\n{metrics}");
        let hit_rate = gauge_value(&metrics, "prefix_hit_rate").unwrap();
        assert!(hit_rate > 0.5, "prefix hit rate {hit_rate}");
        gw.shutdown().unwrap();
    });
}

#[test]
fn f16_storage_more_than_halves_kv_bytes_for_the_shared_prefix_scenario() {
    with_watchdog(120, "f16_kv_bytes", || {
        // The 4-client shared-1024-token-prefix scenario from the streaming
        // test, run once per dtype. Prefix retention pins the shared system
        // prompt, so after all clients finish the resident bytes are a
        // deterministic function of (chunk count, dtype) — and the chunk
        // count is dtype-independent (storage format never changes tree
        // topology). Acceptance: f16 kv_bytes_in_use <= 55% of f32.
        let run = |dtype: KvDtype| -> (f64, String) {
            let cfg = GatewayConfig {
                retain_chunks: 10_000,
                decode_interval: Duration::from_micros(200),
                ..base_cfg()
            };
            let gw = Gateway::start_sharded(
                move |_| {
                    Engine::with_dtype(
                        SyntheticRunner { heads_total: 2, head_dim: 8, vocab: 32000 },
                        64,
                        8,
                        dtype,
                    )
                },
                cfg,
            )
            .unwrap();
            let addr = gw.addr().to_string();
            let system_prompt: Vec<u32> = (0..1024).collect();
            let mut clients = Vec::new();
            for c in 0..4u32 {
                let addr = addr.clone();
                let mut prompt = system_prompt.clone();
                prompt.extend([5000 + c, 6000 + c]);
                clients.push(thread::spawn(move || {
                    let body = token_body(&prompt, 1024, 4);
                    let mut stream =
                        client::generate(&addr, &body, Duration::from_secs(30)).unwrap();
                    assert_eq!(stream.status(), 200, "{}", stream.error_body);
                    while let Some(ev) = stream.next_event().unwrap() {
                        if matches!(ev, StreamEvent::Done { .. }) {
                            break;
                        }
                    }
                }));
            }
            for c in clients {
                c.join().unwrap();
            }
            let metrics = scrape(&addr);
            let bytes = gauge_value(&metrics, "kv_bytes_in_use").unwrap();
            gw.shutdown().unwrap();
            (bytes, metrics)
        };

        let (f32_bytes, f32_metrics) = run(KvDtype::F32);
        let (f16_bytes, f16_metrics) = run(KvDtype::F16);
        assert!(f32_bytes > 0.0, "pinned prefix must stay resident:\n{f32_metrics}");
        assert!(f16_bytes > 0.0, "pinned prefix must stay resident:\n{f16_metrics}");
        assert!(
            f16_bytes <= 0.55 * f32_bytes,
            "f16 kv_bytes_in_use {f16_bytes} must be <= 55% of f32 {f32_bytes}"
        );
        // The dtype is exported as a gauge label for dashboards.
        assert!(
            f16_metrics.contains("kv_dtype_info{dtype=\"f16\"} 1"),
            "missing dtype info gauge:\n{f16_metrics}"
        );
        assert!(f32_metrics.contains("kv_dtype_info{dtype=\"f32\"} 1"));
    });
}

#[test]
fn admission_queue_overflow_returns_429() {
    with_watchdog(60, "backpressure_429", || {
        // One decode slot, one queue slot: the third in-flight request
        // must bounce with 429. All three prompts share an identical
        // 16-token first chunk (declared via shared_tokens), so under a
        // multi-shard router they hash to the same shard and contend for
        // the same admission queue — per-shard admission is the contract.
        let cfg = GatewayConfig {
            queue_cap: 1,
            decode_interval: Duration::from_millis(2),
            ..base_cfg()
        };
        let gw = start_gw(16, 1, cfg);
        let addr = gw.addr().to_string();
        let prefix: Vec<u32> = (0..16).collect();
        let prompt = |tail: [u32; 3]| -> Vec<u32> {
            let mut p = prefix.clone();
            p.extend(tail);
            p
        };

        // A: admitted; wait for its first token so it occupies the batch.
        // Its budget is long enough (2000 tok x 2 ms) that it stays active
        // until explicitly abandoned at the end of the test.
        let mut a = client::generate(
            &addr,
            &token_body(&prompt([1, 2, 3]), 16, 2000),
            Duration::from_secs(30),
        )
        .unwrap();
        assert_eq!(a.status(), 200);
        assert!(matches!(a.next_event().unwrap(), Some(StreamEvent::Token { .. })));

        // B: fills the single queue slot; its response head only arrives
        // once admitted, so run it on its own thread.
        let b_addr = addr.clone();
        let b_prompt = prompt([4, 5, 6]);
        let b = thread::spawn(move || {
            let mut b =
                client::generate(&b_addr, &token_body(&b_prompt, 16, 4), Duration::from_secs(60))
                    .unwrap();
            assert_eq!(b.status(), 200, "queued request eventually streams");
            while let Some(ev) = b.next_event().unwrap() {
                if matches!(ev, StreamEvent::Done { .. }) {
                    return;
                }
            }
            panic!("queued request never completed");
        });
        // Wait until B is observably sitting in the admission queue.
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            if gauge_value(&scrape(&addr), "queue_depth").unwrap() >= 1.0 {
                break;
            }
            assert!(Instant::now() < deadline, "request B never reached the queue");
            thread::sleep(Duration::from_millis(20));
        }

        // C: queue is full -> 429 with a JSON error body.
        let c = client::generate(
            &addr,
            &token_body(&prompt([7, 8, 9]), 16, 4),
            Duration::from_secs(30),
        )
        .unwrap();
        assert_eq!(c.status(), 429, "{}", c.error_body);
        assert!(c.error_body.contains("queue"), "{}", c.error_body);

        let metrics = scrape(&addr);
        assert!(gauge_value(&metrics, "admission_rejections_total").unwrap() >= 1.0);

        // Release the batch slot: dropping A cancels it server-side, B
        // then admits and finishes.
        a.abandon();
        b.join().unwrap();
        gw.shutdown().unwrap();
    });
}

#[test]
fn client_disconnect_releases_private_chunks_to_the_pinned_baseline() {
    with_watchdog(60, "disconnect_cancellation", || {
        // Retention keeps the tenant's system prompt pinned, so the
        // baseline after an idle period is exactly the pinned chunks.
        let cfg = GatewayConfig {
            retain_chunks: 1000,
            decode_interval: Duration::from_millis(1),
            ..base_cfg()
        };
        let gw = start_gw(8, 4, cfg);
        let addr = gw.addr().to_string();
        let system_prompt: Vec<u32> = (0..64).collect();

        // Request 1 completes normally and establishes the pinned baseline.
        let mut prompt = system_prompt.clone();
        prompt.extend([900, 901]);
        let mut warm =
            client::generate(&addr, &token_body(&prompt, 64, 4), Duration::from_secs(30)).unwrap();
        assert_eq!(warm.status(), 200);
        while let Some(ev) = warm.next_event().unwrap() {
            if matches!(ev, StreamEvent::Done { .. }) {
                break;
            }
        }
        let baseline = gauge_value(&scrape(&addr), "chunks_in_use").unwrap();
        assert!(baseline >= 8.0, "64 pinned tokens at chunk=8 need >=8 chunks, got {baseline}");

        // Request 2: same tenant prefix, huge budget; read a few tokens
        // then drop the connection mid-decode.
        let mut prompt2 = system_prompt.clone();
        prompt2.extend([910, 911]);
        let mut doomed =
            client::generate(&addr, &token_body(&prompt2, 64, 5000), Duration::from_secs(30))
                .unwrap();
        assert_eq!(doomed.status(), 200);
        for _ in 0..3 {
            assert!(matches!(doomed.next_event().unwrap(), Some(StreamEvent::Token { .. })));
        }
        doomed.abandon();

        // The failed SSE write triggers Cancel; private chunks return to
        // the pool and only the pinned prefix stays resident.
        let deadline = Instant::now() + Duration::from_secs(20);
        loop {
            let metrics = scrape(&addr);
            let in_use = gauge_value(&metrics, "chunks_in_use").unwrap();
            let cancelled = gauge_value(&metrics, "requests_cancelled_total").unwrap();
            if cancelled >= 1.0 && (in_use - baseline).abs() < 0.5 {
                break;
            }
            assert!(
                Instant::now() < deadline,
                "chunks never returned to baseline {baseline}: in_use={in_use} \
                 cancelled={cancelled}"
            );
            thread::sleep(Duration::from_millis(50));
        }
        gw.shutdown().unwrap();
    });
}

#[test]
fn graceful_shutdown_drains_and_stops_accepting() {
    with_watchdog(60, "graceful_shutdown", || {
        let gw = start_gw(16, 4, base_cfg());
        let addr = gw.addr().to_string();
        let health = client::get(&addr, "/healthz", Duration::from_secs(5)).unwrap();
        assert_eq!(health.status, 200);

        // One quick request end to end.
        let mut s =
            client::generate(&addr, &token_body(&[1, 2, 3, 4], 0, 3), Duration::from_secs(30))
                .unwrap();
        assert_eq!(s.status(), 200);
        let mut done = false;
        while let Some(ev) = s.next_event().unwrap() {
            if matches!(ev, StreamEvent::Done { completion_tokens: 3 }) {
                done = true;
                break;
            }
        }
        assert!(done);

        gw.shutdown().unwrap();
        // The listener is gone: new connections are refused (or reset).
        assert!(client::get(&addr, "/healthz", Duration::from_secs(2)).is_err());
    });
}

#[test]
fn chunked_prefill_interleaves_a_long_cold_prompt_with_live_decode() {
    with_watchdog(90, "chunked_prefill_interleave", || {
        use chunk_attention::coordinator::engine::testing::PacedRunner;
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::Arc;
        // Prefill paced at 30µs/token: a 2048-token cold prompt costs
        // ~61ms of model time. Chunked at 64-token slices under a
        // 128-token step budget, that cost is spread over ~16 engine
        // steps — with a decode step between each pair of slices.
        let runner = PacedRunner {
            inner: SyntheticRunner { heads_total: 2, head_dim: 8, vocab: 32000 },
            prefill_us_per_token: 30,
        };
        let engine = Engine::new(runner, 64, 4);
        let cfg = GatewayConfig {
            prefill_chunk_tokens: 64,
            step_token_budget: 128,
            decode_interval: Duration::from_micros(200),
            ..GatewayConfig::default()
        };
        let gw = Gateway::start(engine, cfg).unwrap();
        let addr = gw.addr().to_string();

        // A short request decodes in the background for the whole test.
        let mut bg =
            client::generate(&addr, &token_body(&[1, 2, 3], 0, 3000), Duration::from_secs(60))
                .unwrap();
        assert_eq!(bg.status(), 200);
        assert!(matches!(bg.next_event().unwrap(), Some(StreamEvent::Token { .. })));

        // The long cold prompt runs on its own thread; the main thread
        // counts background tokens until it completes.
        let done_flag = Arc::new(AtomicBool::new(false));
        let long_addr = addr.clone();
        let long_done = done_flag.clone();
        let long_thread = thread::spawn(move || {
            let long: Vec<u32> = (100_000..102_048).collect();
            let mut s =
                client::generate(&long_addr, &token_body(&long, 0, 2), Duration::from_secs(60))
                    .unwrap();
            assert_eq!(s.status(), 200, "{}", s.error_body);
            while let Some(ev) = s.next_event().unwrap() {
                if matches!(ev, StreamEvent::Done { .. }) {
                    break;
                }
            }
            long_done.store(true, Ordering::SeqCst);
        });
        let mut bg_tokens = 0usize;
        while !done_flag.load(Ordering::SeqCst) {
            match bg.next_event().unwrap() {
                Some(StreamEvent::Token { .. }) => bg_tokens += 1,
                _ => break,
            }
        }
        long_thread.join().unwrap();
        // Under monolithic prefill the whole 61ms is one engine step and
        // the background stream freezes; interleaved, it keeps flowing.
        assert!(
            bg_tokens >= 8,
            "decode starved during the long prefill: only {bg_tokens} background tokens"
        );
        let metrics = scrape(&addr);
        let chunks = gauge_value(&metrics, "prefill_chunks_total").unwrap();
        assert!(chunks >= 32.0, "2048 tokens / 64-token slices => >=32 slices, saw {chunks}");
        let decode_steps = gauge_value(&metrics, "decode_steps_total").unwrap();
        assert!(decode_steps >= 16.0, "decode steps {decode_steps}");
        assert!(metrics.contains("step_token_budget 128"), "{metrics}");
        assert!(metrics.contains("prefill_chunk_tokens 64"), "{metrics}");
        assert!(metrics.contains("prefill_queue_depth"), "{metrics}");
        bg.abandon();
        gw.shutdown().unwrap();
    });
}

#[test]
fn mixed_workload_short_ttft_p99_improves_with_chunked_prefill() {
    with_watchdog(120, "mixed_hol_comparison", || {
        use chunk_attention::server::{run_prefill_comparison, ComparisonConfig, MixedBenchConfig};
        // Long cold prompts at 40µs/token stall a monolithic gateway
        // ~31ms per admission; chunked at a 96-token budget bounds any
        // stall at ~4ms. Short requests' TTFT p99 is the acceptance
        // metric.
        let cfg = ComparisonConfig {
            mixed: MixedBenchConfig {
                addr: String::new(),
                long_clients: 2,
                short_clients: 4,
                long_requests: 6,
                short_requests: 24,
                long_prompt_tokens: 768,
                shared_prefix_tokens: 256,
                short_query_tokens: 8,
                max_new_tokens: 4,
                timeout: Duration::from_secs(60),
            },
            max_batch: 8,
            chunk: 64,
            queue_cap: 64,
            decode_interval: Duration::from_micros(200),
            prefill_us_per_token: 40,
            prefill_chunk_tokens: 64,
            step_token_budget: 96,
            kv_dtype: KvDtype::F32,
        };
        // p99 over 24 samples is effectively a max, and both legs run
        // real sleeps on a shared CI box — one OS scheduling hiccup can
        // invert a single run. The expected gap is large (monolithic
        // stalls ~31ms/admission vs a ~4ms chunked step ceiling), so one
        // retry makes a false failure vanishingly unlikely without
        // weakening the acceptance criterion.
        let mut last = None;
        for attempt in 0..2 {
            let (mono, chunked) = run_prefill_comparison(&cfg).unwrap();
            assert_eq!(mono.errors, 0, "monolithic leg had errors");
            assert_eq!(chunked.errors, 0, "chunked leg had errors");
            assert_eq!(mono.short_completed, 24);
            assert_eq!(chunked.short_completed, 24);
            assert_eq!(mono.long_completed, 6);
            assert_eq!(chunked.long_completed, 6);
            let mono_p99 = mono.short_ttft_ms.percentile(99.0);
            let chunked_p99 = chunked.short_ttft_ms.percentile(99.0);
            if chunked_p99 < mono_p99 {
                return;
            }
            eprintln!(
                "attempt {attempt}: chunked p99 {chunked_p99:.1}ms !< monolithic {mono_p99:.1}ms"
            );
            last = Some((mono_p99, chunked_p99));
        }
        let (mono_p99, chunked_p99) = last.unwrap();
        panic!(
            "chunked prefill must improve short-request TTFT p99 (twice): chunked \
             {chunked_p99:.1}ms vs monolithic {mono_p99:.1}ms"
        );
    });
}

#[test]
fn skewed_tenants_cold_ttft_p99_improves_with_aging() {
    with_watchdog(180, "skewed_policy_comparison", || {
        use chunk_attention::server::{
            run_policy_comparison, MixedBenchConfig, PolicyComparisonConfig,
        };
        // One cold tenant (long unshareable prompts) vs a hot storm of
        // prefix-sharers against a 2-slot batch: under prefix-greedy,
        // every freed slot goes to a queued sharer, so the cold tenant's
        // later requests wait out the storm (tens of ms); under aging the
        // wait boost admits them within a handful of engine steps. The
        // per-step budget-conservation half of this acceptance criterion
        // is asserted at the engine layer (invariants::
        // sched_policies_conserve_the_step_budget_and_decode_identically
        // and the engine's partial-decode/eviction unit tests), where
        // spend is observable per step rather than through scrapes.
        let cfg = PolicyComparisonConfig {
            mixed: MixedBenchConfig {
                addr: String::new(),
                long_clients: 1,
                short_clients: 5,
                long_requests: 4,
                short_requests: 48,
                long_prompt_tokens: 256,
                shared_prefix_tokens: 256,
                short_query_tokens: 4,
                max_new_tokens: 4,
                timeout: Duration::from_secs(60),
            },
            max_batch: 2,
            chunk: 64,
            queue_cap: 64,
            decode_interval: Duration::from_micros(300),
            prefill_us_per_token: 30,
            prefill_chunk_tokens: 64,
            step_token_budget: 96,
            kv_dtype: KvDtype::F32,
            policies: (SchedPolicyKind::PrefixGreedy, SchedPolicyKind::Aging),
        };
        // Wall-clock TTFT on a shared CI box is noisy; the expected gap is
        // large (storm drain time vs a few engine steps), so one retry
        // makes a false failure vanishingly unlikely without weakening
        // the criterion.
        let mut last = None;
        for attempt in 0..2 {
            let (greedy, aging) = run_policy_comparison(&cfg).unwrap();
            assert_eq!(greedy.errors, 0, "prefix-greedy leg had errors");
            assert_eq!(aging.errors, 0, "aging leg had errors");
            assert_eq!(greedy.long_completed, 4);
            assert_eq!(aging.long_completed, 4);
            assert_eq!(greedy.short_completed, 48);
            assert_eq!(aging.short_completed, 48);
            let greedy_p99 = greedy.long_ttft_ms.percentile(99.0);
            let aging_p99 = aging.long_ttft_ms.percentile(99.0);
            if aging_p99 < greedy_p99 {
                return;
            }
            eprintln!(
                "attempt {attempt}: aging cold p99 {aging_p99:.1}ms !< prefix-greedy \
                 {greedy_p99:.1}ms"
            );
            last = Some((greedy_p99, aging_p99));
        }
        let (greedy_p99, aging_p99) = last.unwrap();
        panic!(
            "aging must improve the cold tenant's TTFT p99 (twice): aging {aging_p99:.1}ms vs \
             prefix-greedy {greedy_p99:.1}ms"
        );
    });
}

#[test]
fn metrics_expose_policy_info_and_per_tenant_counters() {
    with_watchdog(60, "policy_metrics", || {
        let cfg = GatewayConfig {
            sched_policy: SchedPolicyKind::Drr,
            tenant_weights: vec![(0, 2)],
            decode_interval: Duration::from_micros(200),
            ..base_cfg()
        };
        let gw = start_gw(16, 4, cfg);
        let addr = gw.addr().to_string();
        for (tenant, tokens) in [(0u64, [1u32, 2, 3]), (7, [9, 9, 9])] {
            let mut body = token_body(&tokens, 0, 3);
            body.set("tenant", tenant);
            let mut s = client::generate(&addr, &body, Duration::from_secs(30)).unwrap();
            assert_eq!(s.status(), 200, "{}", s.error_body);
            while let Some(ev) = s.next_event().unwrap() {
                if matches!(ev, StreamEvent::Done { .. }) {
                    break;
                }
            }
        }
        let metrics = scrape(&addr);
        assert!(
            metrics.contains("sched_policy_info{policy=\"drr\"} 1"),
            "missing policy info gauge:\n{metrics}"
        );
        assert_eq!(
            labeled_gauge_value(&metrics, "tenant_admitted_total", "tenant", "0"),
            Some(1.0),
            "{metrics}"
        );
        assert_eq!(
            labeled_gauge_value(&metrics, "tenant_admitted_total", "tenant", "7"),
            Some(1.0),
            "{metrics}"
        );
        // 3 completion tokens per request, the first credited at prefill.
        assert_eq!(
            labeled_gauge_value(&metrics, "tenant_decode_tokens_total", "tenant", "7"),
            Some(2.0),
            "{metrics}"
        );
        assert!(gauge_value(&metrics, "decode_lag_max").is_some(), "{metrics}");
        gw.shutdown().unwrap();
    });
}

#[test]
fn bench_harness_round_trips_against_a_live_gateway() {
    with_watchdog(120, "bench_http_smoke", || {
        use chunk_attention::server::{run_bench, BenchConfig};
        let cfg = GatewayConfig {
            queue_cap: 64,
            decode_interval: Duration::from_micros(200),
            ..base_cfg()
        };
        let gw = start_gw(64, 8, cfg);
        let report = run_bench(&BenchConfig {
            addr: gw.addr().to_string(),
            clients: 4,
            requests: 12,
            tenants: 2,
            system_tokens: 200,
            query_tokens: 8,
            max_new_tokens: 4,
            seed: 3,
            timeout: Duration::from_secs(60),
        })
        .unwrap();
        assert_eq!(report.completed, 12, "errors={} rejected={}", report.errors, report.rejected);
        assert_eq!(report.errors, 0);
        assert!(report.completion_tokens >= 48);
        assert!(report.ttft_ms.count() == 12);
        assert!(
            report.prefix_hit_rate > 0.3,
            "multi-tenant workload must reuse system prompts, hit rate {}",
            report.prefix_hit_rate
        );
        gw.shutdown().unwrap();
    });
}
