//! Socket-level e2e tests of the prefix-affinity shard router: hash-ring
//! stability under drain/join, zero accepted-request loss across a live
//! membership change, aggregated cluster `/metrics`, and end-to-end
//! `X-Request-Id` propagation.
//!
//! Every test runs under a hard watchdog so a hung accept loop or a
//! deadlocked shard stepper fails the test quickly instead of stalling CI.

use chunk_attention::coordinator::engine::testing::SyntheticRunner;
use chunk_attention::coordinator::Engine;
use chunk_attention::server::client::{self, StreamEvent};
use chunk_attention::server::{
    gauge_value, lint_exposition, routing_key, Gateway, GatewayConfig, HashRing, RING_SEED,
    RING_VNODES,
};
use chunk_attention::util::json::Json;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Run `f` on a worker thread; panic (failing the test fast) if it does
/// not finish within `secs`. The hard per-test timeout for CI.
fn with_watchdog<T: Send + 'static>(
    secs: u64,
    name: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    let worker = thread::spawn(move || {
        let result = f();
        let _ = tx.send(());
        result
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("test {name} exceeded its {secs}s watchdog (hung gateway?)")
        }
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => match worker.join() {
            Ok(result) => result,
            Err(panic) => std::panic::resume_unwind(panic),
        },
    }
}

fn engine(chunk: usize, max_batch: usize) -> Engine<SyntheticRunner> {
    Engine::new(SyntheticRunner { heads_total: 2, head_dim: 8, vocab: 32000 }, chunk, max_batch)
}

fn start_shards(n: usize, chunk: usize, max_batch: usize, cfg: GatewayConfig) -> Gateway {
    let cfg = GatewayConfig { shards: n, ..cfg };
    Gateway::start_sharded(move |_| engine(chunk, max_batch), cfg).unwrap()
}

fn token_body(tokens: &[u32], shared: usize, max_new: usize) -> Json {
    let mut body = Json::obj();
    body.set("tokens", Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()));
    body.set("shared_tokens", shared).set("max_new_tokens", max_new);
    body
}

/// A 32-token tenant prefix for tenant `i`: distinct first chunk, so
/// distinct tenants land on ring-chosen shards while every request of one
/// tenant routes identically.
fn tenant_prefix(i: u32) -> Vec<u32> {
    (i * 1000..i * 1000 + 32).collect()
}

#[test]
fn draining_a_shard_remaps_only_its_keys_and_restarts_route_identically() {
    // Corpus of tenant prefixes -> routing keys, mapped through the same
    // ring construction the gateway uses.
    let keys: Vec<u64> =
        (0..2000u32).map(|i| routing_key(&tenant_prefix(i), 32, 16)).collect();
    let mut ring = HashRing::new(4, RING_VNODES, RING_SEED);
    let before: Vec<usize> = keys.iter().map(|&k| ring.shard_for(k).unwrap()).collect();

    // Every member owns a non-degenerate share of the corpus.
    for shard in 0..4 {
        let share = before.iter().filter(|&&s| s == shard).count() as f64 / keys.len() as f64;
        assert!(
            (0.10..=0.45).contains(&share),
            "shard {shard} owns {share:.2} of the corpus (want roughly 1/4)"
        );
    }

    // Drain shard 2: exactly its keys move, every other key stays put.
    ring.remove(2);
    let after: Vec<usize> = keys.iter().map(|&k| ring.shard_for(k).unwrap()).collect();
    for (i, (&b, &a)) in before.iter().zip(&after).enumerate() {
        if b == 2 {
            assert_ne!(a, 2, "key {i} still routed to the drained shard");
        } else {
            assert_eq!(a, b, "key {i} moved although its shard never drained");
        }
    }

    // Re-join restores the exact pre-drain mapping (drain/join is an
    // involution), and an independently constructed ring — a router
    // restart — routes the whole corpus identically.
    ring.add(2);
    let rejoined: Vec<usize> = keys.iter().map(|&k| ring.shard_for(k).unwrap()).collect();
    assert_eq!(rejoined, before, "join must restore the pre-drain mapping");
    let restarted = HashRing::new(4, RING_VNODES, RING_SEED);
    let fresh: Vec<usize> = keys.iter().map(|&k| restarted.shard_for(k).unwrap()).collect();
    assert_eq!(fresh, before, "a rebuilt ring must route identically (seeded determinism)");
}

#[test]
fn drain_and_join_mid_traffic_lose_no_accepted_requests() {
    with_watchdog(120, "drain_join_zero_loss", || {
        let cfg = GatewayConfig {
            queue_cap: 64,
            decode_interval: Duration::from_millis(1),
            ..GatewayConfig::default()
        };
        let gw = start_shards(3, 16, 8, cfg);
        let addr = gw.addr().to_string();

        // Six tenants stream 60-token completions (>=60ms each at the
        // 1ms decode interval) — plenty of in-flight work to drain under.
        let (ready_tx, ready_rx) = mpsc::channel();
        let mut clients = Vec::new();
        for i in 0..6u32 {
            let addr = addr.clone();
            let ready = ready_tx.clone();
            clients.push(thread::spawn(move || {
                let mut prompt = tenant_prefix(i);
                prompt.extend([9000 + i, 9100 + i]);
                let body = token_body(&prompt, 32, 60);
                let mut stream =
                    client::generate(&addr, &body, Duration::from_secs(60)).unwrap();
                assert_eq!(stream.status(), 200, "{}", stream.error_body);
                let mut tokens = 0usize;
                let mut signalled = false;
                while let Some(ev) = stream.next_event().unwrap() {
                    match ev {
                        StreamEvent::Token { .. } => {
                            tokens += 1;
                            if !signalled {
                                signalled = true;
                                let _ = ready.send(());
                            }
                        }
                        StreamEvent::Done { completion_tokens } => {
                            assert_eq!(
                                completion_tokens, 60,
                                "accepted stream for tenant {i} was cut short"
                            );
                            return tokens;
                        }
                        other => panic!("tenant {i}: unexpected terminal event {other:?}"),
                    }
                }
                panic!("tenant {i}: stream ended without Done");
            }));
        }
        // All six are accepted and actively decoding before the drain.
        for _ in 0..6 {
            ready_rx.recv_timeout(Duration::from_secs(30)).expect("client never got a token");
        }

        // Drain shard 1 mid-traffic: the ring drops its points, the
        // stepper keeps running, in-flight streams finish untouched.
        let resp = client::post(&addr, "/admin/drain?shard=1", Duration::from_secs(10)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let drained = Json::parse(&resp.body).unwrap();
        assert_eq!(drained.get("state").and_then(Json::as_str), Some("draining"));
        let members = drained.get("ring_members").and_then(Json::as_arr).unwrap();
        assert_eq!(members.len(), 2, "3-shard ring minus one drained member");
        assert!(members.iter().all(|m| m.as_f64() != Some(1.0)), "{}", resp.body);

        // The routing table reflects the drain.
        let table = client::get(&addr, "/admin/shards", Duration::from_secs(10)).unwrap();
        assert_eq!(table.status, 200);
        let table = Json::parse(&table.body).unwrap();
        let shards = table.get("shards").and_then(Json::as_arr).unwrap();
        assert_eq!(shards.len(), 3);
        assert_eq!(shards[1].get("draining").and_then(Json::as_bool), Some(true));
        assert_eq!(shards[1].get("in_ring").and_then(Json::as_bool), Some(false));
        assert_eq!(shards[0].get("in_ring").and_then(Json::as_bool), Some(true));

        // New traffic keeps flowing to the surviving shards.
        let mut during = tenant_prefix(77);
        during.extend([7700, 7701]);
        let mut s =
            client::generate(&addr, &token_body(&during, 32, 4), Duration::from_secs(30)).unwrap();
        assert_eq!(s.status(), 200, "admission must survive a drain: {}", s.error_body);
        let mut done = false;
        while let Some(ev) = s.next_event().unwrap() {
            if matches!(ev, StreamEvent::Done { .. }) {
                done = true;
                break;
            }
        }
        assert!(done, "request during drain never completed");

        // Zero loss: every stream accepted before the drain runs to Done
        // with its full completion budget.
        for c in clients {
            assert_eq!(c.join().unwrap(), 60);
        }

        // Join restores the full ring...
        let resp = client::post(&addr, "/admin/join?shard=1", Duration::from_secs(10)).unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body);
        let joined = Json::parse(&resp.body).unwrap();
        assert_eq!(joined.get("state").and_then(Json::as_str), Some("active"));
        assert_eq!(joined.get("ring_members").and_then(Json::as_arr).unwrap().len(), 3);

        // ...and shard 1 serves again: pick a prefix the ring provably
        // assigns to shard 1 (the same construction the router uses) and
        // run it end to end.
        let ring = HashRing::new(3, RING_VNODES, RING_SEED);
        let tenant = (200..)
            .find(|&i| ring.shard_for(routing_key(&tenant_prefix(i), 32, 16)) == Some(1))
            .unwrap();
        let mut prompt = tenant_prefix(tenant);
        prompt.extend([8800, 8801]);
        let mut s =
            client::generate(&addr, &token_body(&prompt, 32, 4), Duration::from_secs(30)).unwrap();
        assert_eq!(s.status(), 200, "rejoined shard must admit: {}", s.error_body);
        let mut done = false;
        while let Some(ev) = s.next_event().unwrap() {
            if matches!(ev, StreamEvent::Done { .. }) {
                done = true;
                break;
            }
        }
        assert!(done, "request to the rejoined shard never completed");

        // Membership error handling: unknown shard and missing parameter.
        let bad = client::post(&addr, "/admin/drain?shard=9", Duration::from_secs(10)).unwrap();
        assert_eq!(bad.status, 404, "{}", bad.body);
        let bad = client::post(&addr, "/admin/drain", Duration::from_secs(10)).unwrap();
        assert_eq!(bad.status, 400, "{}", bad.body);

        gw.shutdown().unwrap();
    });
}

#[test]
fn cluster_metrics_aggregate_rollups_and_per_shard_series() {
    with_watchdog(60, "sharded_metrics", || {
        let cfg = GatewayConfig {
            decode_interval: Duration::from_micros(500),
            ..GatewayConfig::default()
        };
        let gw = start_shards(2, 16, 8, cfg);
        let addr = gw.addr().to_string();

        for i in 0..4u32 {
            let mut prompt = tenant_prefix(i);
            prompt.extend([6000 + i]);
            let mut s = client::generate(&addr, &token_body(&prompt, 32, 3), Duration::from_secs(30))
                .unwrap();
            assert_eq!(s.status(), 200, "{}", s.error_body);
            while let Some(ev) = s.next_event().unwrap() {
                if matches!(ev, StreamEvent::Done { .. }) {
                    break;
                }
            }
        }

        let resp = client::get(&addr, "/metrics", Duration::from_secs(10)).unwrap();
        assert_eq!(resp.status, 200);
        let doc = resp.body;
        let violations = lint_exposition(&doc);
        assert!(violations.is_empty(), "aggregated exposition lint: {violations:?}\n{doc}");
        // Unlabeled rollups stay readable by the suffix-matching helpers
        // (cluster totals), and every shard contributes labeled series.
        assert!(gauge_value(&doc, "decode_steps_total").unwrap() >= 3.0, "{doc}");
        assert_eq!(gauge_value(&doc, "queue_depth"), Some(0.0), "{doc}");
        assert!(doc.contains("shard=\"0\""), "missing shard 0 series:\n{doc}");
        assert!(doc.contains("shard=\"1\""), "missing shard 1 series:\n{doc}");

        // Multi-shard health reports per-shard status under a cluster
        // verdict.
        let health = client::get(&addr, "/healthz", Duration::from_secs(10)).unwrap();
        assert_eq!(health.status, 200, "{}", health.body);
        let health = Json::parse(&health.body).unwrap();
        assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
        assert_eq!(health.get("shards").and_then(Json::as_arr).unwrap().len(), 2);

        // Debug documents arrive as one JSON body per shard.
        let steps = client::get(&addr, "/debug/steps", Duration::from_secs(10)).unwrap();
        assert_eq!(steps.status, 200);
        let steps = Json::parse(&steps.body).unwrap();
        assert_eq!(steps.get("shards").and_then(Json::as_arr).unwrap().len(), 2);

        gw.shutdown().unwrap();
    });
}

#[test]
fn client_request_id_echoes_on_the_sse_stream() {
    with_watchdog(60, "request_id_echo", || {
        let gw = start_shards(2, 16, 4, GatewayConfig::default());
        let addr = gw.addr().to_string();
        let mut prompt = tenant_prefix(3);
        prompt.extend([4242]);
        let body = token_body(&prompt, 32, 2);
        let mut s = client::generate_with_request_id(
            &addr,
            &body,
            Duration::from_secs(30),
            Some("req-e2e-0042"),
        )
        .unwrap();
        assert_eq!(s.status(), 200, "{}", s.error_body);
        assert_eq!(
            s.request_id.as_deref(),
            Some("req-e2e-0042"),
            "gateway must echo X-Request-Id on the stream head"
        );
        let mut done = false;
        while let Some(ev) = s.next_event().unwrap() {
            if matches!(ev, StreamEvent::Done { .. }) {
                done = true;
                break;
            }
        }
        assert!(done);
        gw.shutdown().unwrap();
    });
}
