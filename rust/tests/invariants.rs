//! Property-based tests (via the in-house `util::pbt` harness) on the
//! paper's §3.1 invariants: prefix-tree refcounts/intervals, pool
//! accounting, paging refcounts, sharing-ratio bounds, and kernel
//! equivalence under random workloads.

use chunk_attention::attention::{
    oracle_attention, tpp_attention, tpp_attention_2d, Queries, Tpp2dScratch, TppScratch,
};
use chunk_attention::kvcache::{KvShape, PagedKvCache, PrefixTree, SeqId};
use chunk_attention::util::pbt;
use chunk_attention::util::rng::Pcg64;
use chunk_attention::util::threadpool::ThreadPool;

/// A random prompt workload: tenants with shared prefixes + per-request
/// suffixes, interleaved with removals, decode appends, and multi-token
/// extends (the chunked-prefill growth path — partially prefilled
/// sequences are first-class residents between slices).
#[derive(Debug, Clone)]
enum Op {
    Insert { seq: u64, tenant: u8, suffix: Vec<u32>, prefix_len: usize },
    Remove { idx: usize },
    Append { idx: usize, token: u32 },
    Extend { idx: usize, tokens: Vec<u32> },
}

fn gen_ops(rng: &mut Pcg64) -> Vec<Op> {
    let n = rng.range(1, 40);
    let mut ops = Vec::with_capacity(n);
    let mut next_seq = 0u64;
    for _ in 0..n {
        match rng.below(12) {
            0..=5 => {
                let tenant = rng.below(3) as u8;
                let prefix_len = rng.range(0, 20);
                let suffix: Vec<u32> =
                    (0..rng.range(1, 12)).map(|_| 10_000 + rng.below(50) as u32).collect();
                ops.push(Op::Insert { seq: next_seq, tenant, suffix, prefix_len });
                next_seq += 1;
            }
            6..=7 => ops.push(Op::Remove { idx: rng.range(0, 64) }),
            8..=9 => ops.push(Op::Append { idx: rng.range(0, 64), token: rng.below(1000) as u32 }),
            _ => {
                let tokens: Vec<u32> =
                    (0..rng.range(1, 10)).map(|_| 20_000 + rng.below(40) as u32).collect();
                ops.push(Op::Extend { idx: rng.range(0, 64), tokens });
            }
        }
    }
    ops
}

fn fill(_pos: usize, token: u32, k: &mut [f32], v: &mut [f32]) {
    k.fill(token as f32 * 0.001);
    v.fill(token as f32 * -0.001);
}

fn apply_ops(ops: &[Op], shape: KvShape) -> Result<PrefixTree, String> {
    let mut tree = PrefixTree::new(shape);
    let mut live: Vec<u64> = Vec::new();
    let row = shape.heads * shape.head_dim;
    for op in ops {
        match op {
            Op::Insert { seq, tenant, suffix, prefix_len } => {
                let mut prompt: Vec<u32> =
                    (0..*prefix_len as u32).map(|i| *tenant as u32 * 1000 + i).collect();
                prompt.extend(suffix);
                if prompt.is_empty() {
                    continue;
                }
                tree.insert_sequence(SeqId(*seq), &prompt, &mut fill);
                live.push(*seq);
            }
            Op::Remove { idx } => {
                if !live.is_empty() {
                    let seq = live.remove(idx % live.len());
                    tree.remove_sequence(SeqId(seq));
                }
            }
            Op::Append { idx, token } => {
                if !live.is_empty() {
                    let seq = live[idx % live.len()];
                    let k = vec![*token as f32; row];
                    let v = vec![-(*token as f32); row];
                    tree.append_token(SeqId(seq), *token, &k, &v);
                }
            }
            Op::Extend { idx, tokens } => {
                if !live.is_empty() {
                    let seq = live[idx % live.len()];
                    tree.extend_sequence(SeqId(seq), tokens, &mut fill);
                }
            }
        }
        tree.check_invariants()?;
    }
    Ok(tree)
}

#[test]
fn prefix_tree_invariants_hold_under_random_workloads() {
    let shape = KvShape::new(2, 4, 4);
    pbt::check_shrink("tree-invariants", 0xC0FFEE, pbt::default_cases(), gen_ops, |ops| {
        apply_ops(ops, shape).map(|_| ())
    });
}

#[test]
fn sharing_never_exceeds_logical_tokens() {
    let shape = KvShape::new(1, 2, 8);
    pbt::check("sharing-bounds", 7, pbt::default_cases(), gen_ops, |ops| {
        let tree = apply_ops(ops, shape)?;
        let s = tree.sharing_stats();
        if s.physical_tokens > s.logical_tokens {
            return Err(format!("physical {} > logical {}", s.physical_tokens, s.logical_tokens));
        }
        // §3.1 memory-loss bound, generalised for mid-chunk splits: every
        // partial chunk is either a path tail (≤ 1 per sequence) or a
        // branch point (≤ live_seqs - 1 across the forest), so
        // waste ≤ (c-1) · 2·live_seqs.
        let allocated = s.chunks * 8;
        let bound = s.physical_tokens + 7 * (2 * tree.num_sequences() + 1);
        if allocated > bound {
            return Err(format!("allocated {allocated} over waste bound {bound}"));
        }
        Ok(())
    });
}

#[test]
fn tpp_matches_oracle_on_random_trees() {
    let shape = KvShape::new(2, 8, 4);
    let pool = ThreadPool::new(1);
    pbt::check("tpp-vs-oracle", 0xA11CE, 24, gen_ops, |ops| {
        let mut tree = apply_ops(ops, shape)?;
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        if b == 0 {
            return Ok(());
        }
        let mut rng = Pcg64::seeded(1);
        let mut q = vec![0.0f32; shape.heads * b * shape.head_dim];
        rng.fill_uniform_f32(&mut q, -1.0, 1.0);
        let queries = Queries::new(&q, shape.heads, b, shape.head_dim);
        let expect = oracle_attention(&tree, &ctx, &queries);
        let mut got = vec![0.0f32; expect.len()];
        let mut scratch = TppScratch::new(&shape, b);
        tpp_attention(&tree, &ctx, &queries, &pool, &mut scratch, &mut got);
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            if (g - e).abs() > 3e-4 * (1.0 + e.abs()) {
                return Err(format!("idx {i}: {g} vs {e}"));
            }
        }
        Ok(())
    });
}

#[test]
fn two_d_kernel_matches_oracle_and_is_thread_count_invariant() {
    // Random trees (random live batch sizes fall out of the random
    // insert/remove/append mix) × thread counts {1, 2, 8}: the production
    // 2D-scheduled kernel must match the f64 oracle within 2e-4 AND be
    // bit-identical for every thread count — its run schedule and merge
    // order depend only on the context, never on the pool size.
    let shape = KvShape::new(3, 8, 4);
    let grid = [1usize, 2, 8];
    let pools: Vec<(usize, ThreadPool)> =
        grid.iter().map(|&n| (n, ThreadPool::new(n))).collect();
    let mut baseline: std::collections::BTreeMap<usize, Vec<f32>> = Default::default();
    pbt::check_grid("tpp2d-vs-oracle-grid", 0x2D5EED, 16, &grid, gen_ops, |case, ops, workers| {
        let mut tree = apply_ops(ops, shape)?;
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        if b == 0 {
            return Ok(());
        }
        // Queries depend only on the case index, so every grid point sees
        // the same problem.
        let mut rng = Pcg64::new(0xD00D, case as u64);
        let mut q = vec![0.0f32; shape.heads * b * shape.head_dim];
        rng.fill_uniform_f32(&mut q, -1.0, 1.0);
        let queries = Queries::new(&q, shape.heads, b, shape.head_dim);
        let expect = oracle_attention(&tree, &ctx, &queries);
        let pool = &pools.iter().find(|(n, _)| *n == workers).unwrap().1;
        let mut scratch = Tpp2dScratch::new();
        let mut got = vec![0.0f32; expect.len()];
        tpp_attention_2d(&tree, &ctx, &queries, pool, &mut scratch, &mut got);
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            if (g - e).abs() > 2e-4 * (1.0 + e.abs()) {
                return Err(format!("workers {workers} idx {i}: {g} vs {e}"));
            }
        }
        match baseline.entry(case) {
            std::collections::btree_map::Entry::Vacant(slot) => {
                slot.insert(got);
            }
            std::collections::btree_map::Entry::Occupied(first) => {
                if first.get() != &got {
                    return Err(format!("workers {workers}: output not bit-identical"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn paged_cache_refcounts_hold_under_random_sharing() {
    pbt::check(
        "paged-invariants",
        99,
        pbt::default_cases(),
        |rng| {
            // (n requests, share flags, lengths)
            let n = rng.range(1, 20);
            (0..n)
                .map(|_| (rng.chance(0.5), rng.range(1, 40), rng.range(0, 3) as u64))
                .collect::<Vec<_>>()
        },
        |reqs| {
            let shape = KvShape::new(1, 2, 4);
            let mut cache = PagedKvCache::new(shape, 4);
            let mut donors: Vec<SeqId> = Vec::new();
            for (i, (share, len, remove_after)) in reqs.iter().enumerate() {
                let sid = SeqId(i as u64);
                let prompt: Vec<u32> = (0..*len as u32).collect();
                if *share && !donors.is_empty() {
                    let donor = donors[i % donors.len()];
                    cache.insert_sequence_shared(sid, donor, &prompt, *len / 2, &mut fill);
                } else {
                    cache.insert_sequence(sid, &prompt, &mut fill);
                }
                donors.push(sid);
                cache.append_token(sid, &[0.5, 0.5], &[0.1, 0.1]);
                if *remove_after == 0 && donors.len() > 1 {
                    let victim = donors.remove(0);
                    cache.remove_sequence(victim);
                }
                cache.check_invariants()?;
            }
            Ok(())
        },
    );
}
