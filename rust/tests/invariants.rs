//! Property-based tests (via the in-house `util::pbt` harness) on the
//! paper's §3.1 invariants: prefix-tree refcounts/intervals, pool
//! accounting, paging refcounts, sharing-ratio bounds, and kernel
//! equivalence under random workloads.

use chunk_attention::attention::{
    oracle_attention, tpp_attention, tpp_attention_2d, Queries, Tpp2dScratch, TppScratch,
};
use chunk_attention::coordinator::engine::testing::SyntheticRunner;
use chunk_attention::coordinator::{Engine, PlannerConfig, SchedPolicyKind};
use chunk_attention::kvcache::{KvShape, PagedKvCache, PrefixTree, SeqId};
use chunk_attention::util::pbt;
use chunk_attention::util::rng::Pcg64;
use chunk_attention::util::simd::{self, SimdIsa};
use chunk_attention::util::threadpool::ThreadPool;
use chunk_attention::workload::Request;

/// A random prompt workload: tenants with shared prefixes + per-request
/// suffixes, interleaved with removals, decode appends, and multi-token
/// extends (the chunked-prefill growth path — partially prefilled
/// sequences are first-class residents between slices).
#[derive(Debug, Clone)]
enum Op {
    Insert { seq: u64, tenant: u8, suffix: Vec<u32>, prefix_len: usize },
    Remove { idx: usize },
    Append { idx: usize, token: u32 },
    Extend { idx: usize, tokens: Vec<u32> },
}

fn gen_ops(rng: &mut Pcg64) -> Vec<Op> {
    let n = rng.range(1, 40);
    let mut ops = Vec::with_capacity(n);
    let mut next_seq = 0u64;
    for _ in 0..n {
        match rng.below(12) {
            0..=5 => {
                let tenant = rng.below(3) as u8;
                let prefix_len = rng.range(0, 20);
                let suffix: Vec<u32> =
                    (0..rng.range(1, 12)).map(|_| 10_000 + rng.below(50) as u32).collect();
                ops.push(Op::Insert { seq: next_seq, tenant, suffix, prefix_len });
                next_seq += 1;
            }
            6..=7 => ops.push(Op::Remove { idx: rng.range(0, 64) }),
            8..=9 => ops.push(Op::Append { idx: rng.range(0, 64), token: rng.below(1000) as u32 }),
            _ => {
                let tokens: Vec<u32> =
                    (0..rng.range(1, 10)).map(|_| 20_000 + rng.below(40) as u32).collect();
                ops.push(Op::Extend { idx: rng.range(0, 64), tokens });
            }
        }
    }
    ops
}

fn fill(_pos: usize, token: u32, k: &mut [f32], v: &mut [f32]) {
    k.fill(token as f32 * 0.001);
    v.fill(token as f32 * -0.001);
}

fn apply_ops(ops: &[Op], shape: KvShape) -> Result<PrefixTree, String> {
    let mut tree = PrefixTree::new(shape);
    let mut live: Vec<u64> = Vec::new();
    let row = shape.heads * shape.head_dim;
    for op in ops {
        match op {
            Op::Insert { seq, tenant, suffix, prefix_len } => {
                let mut prompt: Vec<u32> =
                    (0..*prefix_len as u32).map(|i| *tenant as u32 * 1000 + i).collect();
                prompt.extend(suffix);
                if prompt.is_empty() {
                    continue;
                }
                tree.insert_sequence(SeqId(*seq), &prompt, &mut fill);
                live.push(*seq);
            }
            Op::Remove { idx } => {
                if !live.is_empty() {
                    let seq = live.remove(idx % live.len());
                    tree.remove_sequence(SeqId(seq));
                }
            }
            Op::Append { idx, token } => {
                if !live.is_empty() {
                    let seq = live[idx % live.len()];
                    let k = vec![*token as f32; row];
                    let v = vec![-(*token as f32); row];
                    tree.append_token(SeqId(seq), *token, &k, &v);
                }
            }
            Op::Extend { idx, tokens } => {
                if !live.is_empty() {
                    let seq = live[idx % live.len()];
                    tree.extend_sequence(SeqId(seq), tokens, &mut fill);
                }
            }
        }
        tree.check_invariants()?;
    }
    Ok(tree)
}

#[test]
fn prefix_tree_invariants_hold_under_random_workloads() {
    let shape = KvShape::new(2, 4, 4);
    pbt::check_shrink("tree-invariants", 0xC0FFEE, pbt::default_cases(), gen_ops, |ops| {
        apply_ops(ops, shape).map(|_| ())
    });
}

#[test]
fn sharing_never_exceeds_logical_tokens() {
    let shape = KvShape::new(1, 2, 8);
    pbt::check("sharing-bounds", 7, pbt::default_cases(), gen_ops, |ops| {
        let tree = apply_ops(ops, shape)?;
        let s = tree.sharing_stats();
        if s.physical_tokens > s.logical_tokens {
            return Err(format!("physical {} > logical {}", s.physical_tokens, s.logical_tokens));
        }
        // §3.1 memory-loss bound, generalised for mid-chunk splits: every
        // partial chunk is either a path tail (≤ 1 per sequence) or a
        // branch point (≤ live_seqs - 1 across the forest), so
        // waste ≤ (c-1) · 2·live_seqs.
        let allocated = s.chunks * 8;
        let bound = s.physical_tokens + 7 * (2 * tree.num_sequences() + 1);
        if allocated > bound {
            return Err(format!("allocated {allocated} over waste bound {bound}"));
        }
        Ok(())
    });
}

#[test]
fn tpp_matches_oracle_on_random_trees() {
    let shape = KvShape::new(2, 8, 4);
    let pool = ThreadPool::new(1);
    pbt::check("tpp-vs-oracle", 0xA11CE, 24, gen_ops, |ops| {
        let mut tree = apply_ops(ops, shape)?;
        let ctx = tree.context();
        let b = ctx.seq_order.len();
        if b == 0 {
            return Ok(());
        }
        let mut rng = Pcg64::seeded(1);
        let mut q = vec![0.0f32; shape.heads * b * shape.head_dim];
        rng.fill_uniform_f32(&mut q, -1.0, 1.0);
        let queries = Queries::new(&q, shape.heads, b, shape.head_dim);
        let expect = oracle_attention(&tree, &ctx, &queries);
        let mut got = vec![0.0f32; expect.len()];
        let mut scratch = TppScratch::new(&shape, b);
        tpp_attention(&tree, &ctx, &queries, &pool, &mut scratch, &mut got);
        for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
            if (g - e).abs() > 3e-4 * (1.0 + e.abs()) {
                return Err(format!("idx {i}: {g} vs {e}"));
            }
        }
        Ok(())
    });
}

/// The ISA axis of the property grids: every path runnable on this host,
/// unless `PALLAS_SIMD=scalar` pins the whole process (the CI scalar leg) —
/// then the grid stays scalar-only so that leg really never executes a
/// vector body.
fn isa_grid() -> Vec<SimdIsa> {
    if simd::env_request() == "scalar" {
        vec![SimdIsa::Scalar]
    } else {
        simd::available()
    }
}

#[test]
fn two_d_kernel_matches_oracle_and_is_thread_count_invariant() {
    // Random trees (random live batch sizes fall out of the random
    // insert/remove/append mix) × thread counts {1, 2, 8} × every ISA path
    // available on this host: the production 2D-scheduled kernel must match
    // the f64 oracle within 2e-4 AND be bit-identical across the whole grid
    // — its run schedule and merge order depend only on the context, never
    // on the pool size, and the SIMD bodies replicate the scalar reduction
    // geometry exactly (DESIGN.md "The SIMD dispatch seam").
    let shape = KvShape::new(3, 8, 4);
    let threads = [1usize, 2, 8];
    let pools: Vec<(usize, ThreadPool)> =
        threads.iter().map(|&n| (n, ThreadPool::new(n))).collect();
    let mut grid: Vec<(usize, SimdIsa)> = Vec::new();
    for &n in &threads {
        for isa in isa_grid() {
            grid.push((n, isa));
        }
    }
    let mut baseline: std::collections::BTreeMap<usize, Vec<f32>> = Default::default();
    pbt::check_grid(
        "tpp2d-vs-oracle-grid",
        0x2D5EED,
        16,
        &grid,
        gen_ops,
        |case, ops, (workers, isa)| {
            let mut tree = apply_ops(ops, shape)?;
            let ctx = tree.context();
            let b = ctx.seq_order.len();
            if b == 0 {
                return Ok(());
            }
            // Queries depend only on the case index, so every grid point sees
            // the same problem.
            let mut rng = Pcg64::new(0xD00D, case as u64);
            let mut q = vec![0.0f32; shape.heads * b * shape.head_dim];
            rng.fill_uniform_f32(&mut q, -1.0, 1.0);
            let queries = Queries::new(&q, shape.heads, b, shape.head_dim);
            let expect = oracle_attention(&tree, &ctx, &queries);
            let pool = &pools.iter().find(|(n, _)| *n == workers).unwrap().1;
            simd::force(Some(isa));
            let mut scratch = Tpp2dScratch::new();
            let mut got = vec![0.0f32; expect.len()];
            tpp_attention_2d(&tree, &ctx, &queries, pool, &mut scratch, &mut got);
            for (i, (g, e)) in got.iter().zip(&expect).enumerate() {
                if (g - e).abs() > 2e-4 * (1.0 + e.abs()) {
                    return Err(format!("workers {workers} isa {} idx {i}: {g} vs {e}", isa.label()));
                }
            }
            match baseline.entry(case) {
                std::collections::btree_map::Entry::Vacant(slot) => {
                    slot.insert(got);
                }
                std::collections::btree_map::Entry::Occupied(first) => {
                    if first.get() != &got {
                        return Err(format!(
                            "workers {workers} isa {}: output not bit-identical",
                            isa.label()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
    simd::force(None);
}

/// A random multi-tenant serving workload for the policy grid: shared
/// tenant prefixes + private suffixes, a tight step budget, and a small
/// retention budget so amortized pin eviction is exercised too.
#[derive(Debug, Clone)]
struct PolicyWorkload {
    step_budget: usize,
    prefill_chunk: usize,
    max_batch: usize,
    retain_chunks: usize,
    /// (id, tenant, prompt, shared, completion)
    requests: Vec<(u64, usize, Vec<u32>, usize, usize)>,
}

fn gen_policy_workload(rng: &mut Pcg64) -> PolicyWorkload {
    let n = rng.range(3, 8);
    let requests = (0..n)
        .map(|i| {
            let tenant = rng.below(3) as usize;
            let shared = rng.range(0, 16);
            let mut prompt: Vec<u32> =
                (0..shared as u32).map(|t| tenant as u32 * 1000 + t).collect();
            prompt.extend((0..rng.range(1, 4)).map(|_| 9000 + rng.below(64) as u32));
            let shared = shared.min(prompt.len());
            (i as u64, tenant, prompt, shared, rng.range(1, 5))
        })
        .collect();
    PolicyWorkload {
        step_budget: rng.range(6, 24),
        prefill_chunk: rng.range(2, 8),
        max_batch: rng.range(2, 4),
        retain_chunks: if rng.chance(0.5) { rng.range(2, 5) } else { 0 },
        requests,
    }
}

#[test]
fn sched_policies_conserve_the_step_budget_and_decode_identically() {
    // Extends the check_grid discipline to the scheduling-policy seam:
    // every policy (the grid) sees the SAME random workloads (the cases),
    // and per engine step the spend — prefill slices + partial decode +
    // eviction-token grants — must stay within the step budget; at the
    // end, per-request completions must be bit-identical across policies
    // (a policy reorders *who* runs, never *what* a sequence decodes),
    // and the tree invariants must hold. A final kernel pass over the
    // workload's prompt tree re-asserts thread-count bit-identity under
    // the policy-shaped trees.
    let grid = [SchedPolicyKind::PrefixGreedy, SchedPolicyKind::Drr, SchedPolicyKind::Aging];
    let pools: Vec<(usize, ThreadPool)> =
        [1usize, 2, 8].iter().map(|&n| (n, ThreadPool::new(n))).collect();
    let mut baseline: std::collections::BTreeMap<usize, Vec<Vec<u32>>> = Default::default();
    pbt::check_grid(
        "policy-budget-grid",
        0xB0D9E7,
        12,
        &grid,
        gen_policy_workload,
        |case, wl, policy| {
            let mut e = Engine::new(
                SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 101 },
                4,
                wl.max_batch,
            );
            e.set_chunked_prefill(wl.prefill_chunk, wl.step_budget);
            if wl.retain_chunks > 0 {
                e.enable_prefix_retention(wl.retain_chunks);
            }
            e.set_planner_config(PlannerConfig {
                policy,
                // Small quantum/boost so DRR and aging take several
                // rounds — the interesting regime.
                drr_quantum: 8,
                aging_boost_tokens: 4,
                evict_step_tokens: 4,
                ..PlannerConfig::default()
            });
            for (id, tenant, prompt, shared, completion) in &wl.requests {
                e.submit(Request {
                    id: *id,
                    arrival_s: 0.0,
                    tenant: *tenant,
                    prompt: prompt.clone(),
                    shared_tokens: *shared,
                    max_new_tokens: *completion,
                });
            }
            // The clamp guarantees an effective budget of at least 2.
            let budget = wl.step_budget.max(2);
            let mut prev = e.stats();
            let mut prev_evict = 0u64;
            let mut steps = 0usize;
            while !e.is_idle() {
                e.step().map_err(|err| format!("engine step failed: {err}"))?;
                steps += 1;
                if steps > 10_000 {
                    return Err("policy livelocked the engine".to_string());
                }
                let s = e.stats();
                let evict =
                    e.retainer().map(|r| r.eviction_tokens_total()).unwrap_or(0);
                let spent = (s.prefill_tokens_computed - prev.prefill_tokens_computed)
                    + (s.decoded_tokens - prev.decoded_tokens)
                    + (evict - prev_evict);
                if spent > budget as u64 {
                    return Err(format!(
                        "policy {policy:?} spent {spent} tokens in one step, budget {budget}"
                    ));
                }
                prev = s;
                prev_evict = evict;
            }
            e.tree().check_invariants()?;
            let completions: Vec<Vec<u32>> = wl
                .requests
                .iter()
                .map(|(id, ..)| e.completion_of(*id).expect("request completed").to_vec())
                .collect();
            match baseline.entry(case) {
                std::collections::btree_map::Entry::Vacant(slot) => {
                    slot.insert(completions);
                }
                std::collections::btree_map::Entry::Occupied(first) => {
                    if first.get() != &completions {
                        return Err(format!(
                            "policy {policy:?} changed a completion (policies may reorder \
                             admissions, never decoded tokens)"
                        ));
                    }
                }
            }
            // Thread-count bit-identity on a tree shaped like this
            // workload's resident state: rebuild the prompts into a fresh
            // tree and require `tpp_attention_2d` to produce bitwise-equal
            // output for every pool size.
            let shape = KvShape::new(2, 4, 4);
            let mut tree = PrefixTree::new(shape);
            for (id, _, prompt, ..) in &wl.requests {
                tree.insert_sequence(SeqId(*id), prompt, &mut fill);
            }
            let ctx = tree.context();
            let b = ctx.seq_order.len();
            let mut rng = Pcg64::new(0xFA1C, case as u64);
            let mut q = vec![0.0f32; shape.heads * b * shape.head_dim];
            rng.fill_uniform_f32(&mut q, -1.0, 1.0);
            let queries = Queries::new(&q, shape.heads, b, shape.head_dim);
            let mut reference: Option<Vec<f32>> = None;
            for (workers, pool) in &pools {
                let mut scratch = Tpp2dScratch::new();
                let mut got = vec![0.0f32; shape.heads * b * shape.head_dim];
                tpp_attention_2d(&tree, &ctx, &queries, pool, &mut scratch, &mut got);
                match &reference {
                    None => reference = Some(got),
                    Some(r) => {
                        if r != &got {
                            return Err(format!(
                                "{workers}-thread kernel output not bit-identical"
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn paged_cache_refcounts_hold_under_random_sharing() {
    pbt::check(
        "paged-invariants",
        99,
        pbt::default_cases(),
        |rng| {
            // (n requests, share flags, lengths)
            let n = rng.range(1, 20);
            (0..n)
                .map(|_| (rng.chance(0.5), rng.range(1, 40), rng.range(0, 3) as u64))
                .collect::<Vec<_>>()
        },
        |reqs| {
            let shape = KvShape::new(1, 2, 4);
            let mut cache = PagedKvCache::new(shape, 4);
            let mut donors: Vec<SeqId> = Vec::new();
            for (i, (share, len, remove_after)) in reqs.iter().enumerate() {
                let sid = SeqId(i as u64);
                let prompt: Vec<u32> = (0..*len as u32).collect();
                if *share && !donors.is_empty() {
                    let donor = donors[i % donors.len()];
                    cache.insert_sequence_shared(sid, donor, &prompt, *len / 2, &mut fill);
                } else {
                    cache.insert_sequence(sid, &prompt, &mut fill);
                }
                donors.push(sid);
                cache.append_token(sid, &[0.5, 0.5], &[0.1, 0.1]);
                if *remove_after == 0 && donors.len() > 1 {
                    let victim = donors.remove(0);
                    cache.remove_sequence(victim);
                }
                cache.check_invariants()?;
            }
            Ok(())
        },
    );
}
