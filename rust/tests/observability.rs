//! Socket-level e2e tests of the observability stack: Prometheus
//! histograms on `/metrics` (content type, exposition lint, quantile
//! reads), the `/debug/steps` and `/debug/tree` JSON snapshots, and the
//! Chrome `trace_event` file written via `GatewayConfig::trace_path` —
//! all observed through a gateway running the real two-phase-partition
//! kernel ([`KernelRunner`]), so the per-phase histograms and kernel
//! spans carry actual `chunk_first` / `seq_first` timings.
//!
//! Every test runs under a hard watchdog so a hung accept loop or a
//! deadlocked stepper fails the test quickly instead of stalling CI.

use chunk_attention::coordinator::engine::testing::KernelRunner;
use chunk_attention::coordinator::Engine;
use chunk_attention::server::client::{self, StreamEvent};
use chunk_attention::server::{histogram_snapshot, lint_exposition, Gateway, GatewayConfig};
use chunk_attention::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Run `f` on a worker thread; panic (failing the test fast) if it does
/// not finish within `secs`. The hard per-test timeout for CI.
fn with_watchdog<T: Send + 'static>(
    secs: u64,
    name: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    let worker = thread::spawn(move || {
        let result = f();
        let _ = tx.send(());
        result
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("test {name} exceeded its {secs}s watchdog (hung gateway?)")
        }
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => match worker.join() {
            Ok(result) => result,
            Err(panic) => std::panic::resume_unwind(panic),
        },
    }
}

fn engine(chunk: usize, max_batch: usize) -> Engine<KernelRunner> {
    Engine::new(KernelRunner::new(2, 8, 32000), chunk, max_batch)
}

fn token_body(tokens: &[u32], shared: usize, max_new: usize) -> Json {
    let mut body = Json::obj();
    body.set("tokens", Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()));
    body.set("shared_tokens", shared).set("max_new_tokens", max_new);
    body
}

fn scrape(addr: &str) -> String {
    let resp = client::get(addr, "/metrics", Duration::from_secs(10)).expect("scrape /metrics");
    assert_eq!(resp.status, 200);
    resp.body
}

/// Raw GET keeping the response headers, which [`client::get`] discards —
/// the exposition content-type assertions need them verbatim.
fn raw_get(addr: &str, path: &str) -> (u16, Vec<String>, String) {
    let mut stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.set_write_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").unwrap();
    stream.flush().unwrap();
    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 =
        status_line.split_whitespace().nth(1).expect("status line").parse().expect("status code");
    let mut headers = Vec::new();
    loop {
        let mut h = String::new();
        assert!(reader.read_line(&mut h).unwrap() > 0, "EOF inside headers");
        let t = h.trim_end().to_string();
        if t.is_empty() {
            break;
        }
        headers.push(t);
    }
    let mut body = String::new();
    reader.read_to_string(&mut body).unwrap();
    (status, headers, body)
}

fn run_to_done(addr: &str, body: &Json) {
    let mut s = client::generate(addr, body, Duration::from_secs(30)).unwrap();
    assert_eq!(s.status(), 200, "{}", s.error_body);
    while let Some(ev) = s.next_event().unwrap() {
        if matches!(ev, StreamEvent::Done { .. }) {
            return;
        }
    }
    panic!("stream ended without Done");
}

#[test]
fn metrics_exposition_has_prometheus_content_type_and_passes_lint() {
    with_watchdog(60, "exposition_lint", || {
        let cfg = GatewayConfig {
            decode_interval: Duration::from_micros(200),
            ..GatewayConfig::default()
        };
        let gw = Gateway::start(engine(16, 4), cfg).unwrap();
        let addr = gw.addr().to_string();
        // One request end to end so every histogram family has samples.
        run_to_done(&addr, &token_body(&[1, 2, 3, 4], 0, 4));

        let (status, headers, body) = raw_get(&addr, "/metrics");
        assert_eq!(status, 200);
        assert!(
            headers
                .iter()
                .any(|h| h == "Content-Type: text/plain; version=0.0.4; charset=utf-8"),
            "missing Prometheus 0.0.4 content type, headers: {headers:?}"
        );
        assert!(body.ends_with('\n'), "exposition must end with a newline");

        // promtool-style lint: HELP/TYPE present once per family, buckets
        // cumulative/monotone ending at +Inf matching _count, no duplicate
        // series. An empty violation list is the acceptance criterion the
        // CI exposition-lint leg runs this test for.
        let violations = lint_exposition(&body);
        assert!(violations.is_empty(), "exposition lint violations: {violations:#?}\n{body}");

        // All four histogram families are present and well formed.
        for family in
            ["ttft_seconds", "inter_token_seconds", "step_duration_seconds", "step_phase_seconds"]
        {
            assert!(
                body.contains(&format!("_{family}_bucket")),
                "missing histogram family {family}:\n{body}"
            );
        }
        gw.shutdown().unwrap();
    });
}

#[test]
fn debug_endpoints_serve_json_on_an_idle_gateway() {
    with_watchdog(60, "debug_idle", || {
        let gw = Gateway::start(engine(16, 2), GatewayConfig::default()).unwrap();
        let addr = gw.addr().to_string();

        let (status, headers, body) = raw_get(&addr, "/debug/steps");
        assert_eq!(status, 200);
        assert!(
            headers.iter().any(|h| h == "Content-Type: application/json"),
            "headers: {headers:?}"
        );
        let steps = Json::parse(&body).expect("valid /debug/steps JSON");
        assert!(steps.get("count").and_then(Json::as_f64).is_some(), "{body}");
        assert!(steps.get("steps").and_then(Json::as_arr).is_some(), "{body}");

        let (status, _, body) = raw_get(&addr, "/debug/tree");
        assert_eq!(status, 200);
        let tree = Json::parse(&body).expect("valid /debug/tree JSON");
        assert_eq!(tree.get("sequences").and_then(Json::as_f64), Some(0.0), "{body}");
        let tokens = tree.get("tokens").expect("tokens object");
        assert!(tokens.get("logical").and_then(Json::as_f64).is_some(), "{body}");
        assert!(tree.get("retain").and_then(|r| r.get("enabled")).is_some(), "{body}");
        gw.shutdown().unwrap();
    });
}

#[test]
fn shared_prefix_run_populates_histograms_debug_snapshots_and_chrome_trace() {
    with_watchdog(120, "observability_e2e", || {
        let trace_path = std::env::temp_dir()
            .join(format!("chunk_attn_observability_trace_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&trace_path);
        let cfg = GatewayConfig {
            decode_interval: Duration::from_micros(300),
            trace_path: Some(trace_path.clone()),
            ..GatewayConfig::default()
        };
        let gw = Gateway::start(engine(64, 8), cfg).unwrap();
        let addr = gw.addr().to_string();
        let system_prompt: Vec<u32> = (0..1024).collect();

        // 4 concurrent clients share the 1024-token system prefix, so
        // decode steps walk shared chunks (phase 1, chunk-first) and each
        // sequence's private suffix (phase 2, seq-first).
        let mut clients = Vec::new();
        for c in 0..4u32 {
            let addr = addr.clone();
            let mut prompt = system_prompt.clone();
            prompt.extend([5000 + c, 6000 + c]);
            clients.push(thread::spawn(move || {
                run_to_done(&addr, &token_body(&prompt, 1024, 32));
            }));
        }
        for c in clients {
            c.join().unwrap();
        }

        // A live stream keeps sequences resident while /debug/tree is
        // snapshotted mid-decode.
        let mut live_prompt = system_prompt.clone();
        live_prompt.extend([7000, 7001]);
        let mut live =
            client::generate(&addr, &token_body(&live_prompt, 1024, 5000), Duration::from_secs(30))
                .unwrap();
        assert_eq!(live.status(), 200, "{}", live.error_body);
        for _ in 0..3 {
            assert!(matches!(live.next_event().unwrap(), Some(StreamEvent::Token { .. })));
        }

        let (status, _, body) = raw_get(&addr, "/debug/tree");
        assert_eq!(status, 200);
        let tree = Json::parse(&body).expect("valid /debug/tree JSON");
        assert!(tree.get("sequences").and_then(Json::as_f64).unwrap() >= 1.0, "{body}");
        let tokens = tree.get("tokens").expect("tokens object");
        assert!(tokens.get("logical").and_then(Json::as_f64).unwrap() >= 1024.0, "{body}");
        assert!(tokens.get("sharing_ratio").and_then(Json::as_f64).is_some(), "{body}");
        let ctx = tree.get("context").expect("context object");
        assert!(ctx.get("shared_chunks").and_then(Json::as_f64).is_some(), "{body}");
        assert!(ctx.get("private_chunks").and_then(Json::as_f64).is_some(), "{body}");
        assert!(tree.get("max_chunk_depth").and_then(Json::as_f64).unwrap() >= 16.0, "{body}");
        live.abandon();

        // The step ring has real per-phase wall times.
        let (status, _, body) = raw_get(&addr, "/debug/steps");
        assert_eq!(status, 200);
        let steps = Json::parse(&body).expect("valid /debug/steps JSON");
        assert!(steps.get("count").and_then(Json::as_f64).unwrap() >= 1.0, "{body}");
        let ring = steps.get("steps").and_then(Json::as_arr).unwrap();
        assert!(!ring.is_empty());
        let phases = ring[0].get("phases").expect("phases object");
        for phase in ["plan", "prefill", "chunk_first", "seq_first", "append", "evict"] {
            assert!(phases.get(phase).and_then(Json::as_f64).is_some(), "{phase} in {body}");
        }

        // Server-side latency histograms accumulated over the run: TTFT
        // once per finished request, inter-token gaps, step durations, and
        // both kernel phases of the two-phase partition.
        let metrics = scrape(&addr);
        assert!(lint_exposition(&metrics).is_empty(), "{:?}", lint_exposition(&metrics));
        let ttft = histogram_snapshot(&metrics, "ttft_seconds", None).expect("ttft histogram");
        assert!(ttft.count >= 4, "4 finished requests, ttft count {}:\n{metrics}", ttft.count);
        assert!(ttft.sum > 0.0);
        assert!(ttft.quantile(0.5) > 0.0, "ttft p50 must be positive");
        let itl = histogram_snapshot(&metrics, "inter_token_seconds", None).expect("itl histogram");
        assert!(itl.count > 0, "{metrics}");
        let steps_h =
            histogram_snapshot(&metrics, "step_duration_seconds", None).expect("step histogram");
        assert!(steps_h.count > 0, "{metrics}");
        let chunk_first =
            histogram_snapshot(&metrics, "step_phase_seconds", Some(("phase", "chunk_first")))
                .expect("chunk_first child");
        assert!(
            chunk_first.count > 0 && chunk_first.sum > 0.0,
            "chunk-first phase must accumulate over a shared-prefix run: count {} sum {}\n{metrics}",
            chunk_first.count,
            chunk_first.sum,
        );
        let seq_first =
            histogram_snapshot(&metrics, "step_phase_seconds", Some(("phase", "seq_first")))
                .expect("seq_first child");
        assert!(seq_first.count > 0, "{metrics}");

        // Shutdown flushes the Chrome trace; it must parse as trace_event
        // JSON and contain step spans with BOTH kernel phases plus the
        // request lifecycle instants.
        gw.shutdown().unwrap();
        let text = std::fs::read_to_string(&trace_path).expect("trace file written");
        let doc = Json::parse(&text).expect("trace file is valid JSON");
        let events = doc.as_arr().expect("trace_event array");
        assert!(!events.is_empty(), "trace must not be empty");
        let names_of = |ph: &str| -> Vec<&str> {
            events
                .iter()
                .filter(|e| e.get("ph").and_then(Json::as_str) == Some(ph))
                .filter_map(|e| e.get("name").and_then(Json::as_str))
                .collect()
        };
        let spans = names_of("X");
        for span in ["step", "chunk_first", "seq_first"] {
            assert!(spans.contains(&span), "missing {span:?} span; spans seen: {spans:?}");
        }
        let instants = names_of("i");
        for instant in ["queued", "finished"] {
            assert!(
                instants.contains(&instant),
                "missing {instant:?} lifecycle event; instants seen: {instants:?}"
            );
        }
        let _ = std::fs::remove_file(&trace_path);
    });
}
