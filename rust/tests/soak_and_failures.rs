//! Soak and failure-injection tests: long-running engine churn with
//! invariants checked continuously, and corrupted-artifact handling.

use chunk_attention::coordinator::engine::testing::SyntheticRunner;
use chunk_attention::coordinator::Engine;
use chunk_attention::runtime::Manifest;
use chunk_attention::util::rng::Pcg64;
use chunk_attention::workload::Request;

#[test]
fn engine_soak_random_churn_keeps_invariants() {
    // 300 requests with random tenants/lengths trickling through a small
    // batch, with retention enabled — the worst structural churn the tree
    // sees in production. Invariants checked every few iterations.
    let mut engine =
        Engine::new(SyntheticRunner { heads_total: 2, head_dim: 8, vocab: 257 }, 4, 6);
    engine.enable_prefix_retention(64);
    let mut rng = Pcg64::seeded(2024);
    let mut submitted = 0u64;
    let mut finished = 0usize;
    let mut iters = 0usize;
    while finished < 300 {
        // Trickle 0-2 new requests per iteration.
        for _ in 0..rng.below(3) {
            if submitted < 300 {
                let tenant = rng.below(5) as u32;
                let sys_len = 4 + (tenant as usize) * 3;
                let mut prompt: Vec<u32> =
                    (0..sys_len as u32).map(|i| tenant * 1000 + i).collect();
                prompt.extend((0..rng.range(1, 6)).map(|_| 50_000 + rng.below(100) as u32));
                engine.submit(Request {
                    id: submitted,
                    arrival_s: 0.0,
                    tenant: tenant as usize,
                    shared_tokens: sys_len,
                    prompt,
                    max_new_tokens: rng.range(1, 9),
                });
                submitted += 1;
            }
        }
        finished += engine.step().unwrap().len();
        iters += 1;
        if iters % 7 == 0 {
            engine.tree().check_invariants().unwrap_or_else(|e| panic!("iter {iters}: {e}"));
        }
        assert!(iters < 10_000, "soak did not converge");
    }
    engine.tree().check_invariants().unwrap();
    // Only retained pins remain; bounded by the retention budget.
    assert!(engine.tree().pool().in_use() <= 64);
    let stats = engine.stats();
    assert!(stats.prefill_tokens_reused > 0, "sharing must have occurred");
    assert_eq!(engine.metrics().requests().len(), 300);
}

#[test]
fn manifest_missing_directory_fails_cleanly() {
    let err = Manifest::load(std::path::Path::new("/nonexistent/artifacts")).unwrap_err();
    assert!(err.to_string().contains("make artifacts"), "{err}");
}

#[test]
fn manifest_rejects_corrupt_json() {
    let dir = std::env::temp_dir().join(format!("chunk-attn-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json").unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(err.to_string().contains("parse"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_rejects_truncated_weights() {
    // Build a minimal-but-valid manifest whose weights blob is too short.
    let dir = std::env::temp_dir().join(format!("chunk-attn-test-w-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = r#"{
      "model": {"name": "mini", "n_layers": 2, "d_model": 256, "heads": 4,
                 "head_dim": 64, "ffn_dim": 512, "vocab": 2048, "heads_total": 8},
      "weights_file": "w.bin",
      "weights": [{"name": "['embed']", "shape": [4, 4]}],
      "artifacts": []
    }"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    std::fs::write(dir.join("w.bin"), [0u8; 8]).unwrap(); // wants 64 bytes
    let m = Manifest::load(&dir).unwrap();
    let err = m.load_weights().unwrap_err();
    assert!(err.to_string().contains("bytes"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn manifest_rejects_wrong_model_config() {
    let dir = std::env::temp_dir().join(format!("chunk-attn-test-m-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // d_model mismatching ModelConfig::mini() must be rejected loudly.
    let manifest = r#"{
      "model": {"name": "mini", "n_layers": 2, "d_model": 512, "heads": 4,
                 "head_dim": 64, "ffn_dim": 512, "vocab": 2048, "heads_total": 8},
      "weights_file": "w.bin", "weights": [], "artifacts": []
    }"#;
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(err.to_string().contains("re-run make artifacts"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
