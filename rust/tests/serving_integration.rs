//! Integration across workload → scheduler → engine/simulator: the serving
//! stack end to end with the synthetic runner (no artifacts required).

use chunk_attention::coordinator::engine::testing::SyntheticRunner;
use chunk_attention::coordinator::{simulate, Engine, SimConfig, SystemKind};
use chunk_attention::kvcache::SeqId;
use chunk_attention::model::ModelConfig;
use chunk_attention::perf_model::HardwareModel;
use chunk_attention::util::rng::Pcg64;
use chunk_attention::workload::{Corpus, Tokenizer, Trace, TraceConfig};

#[test]
fn corpus_driven_engine_run_shares_prefixes() {
    let tok = Tokenizer::train("the quick brown fox jumps over the lazy dog. api search query", 120);
    let corpus = Corpus::synthesize(&tok, 2, 60, 11);
    let mut rng = Pcg64::seeded(4);

    let mut engine =
        Engine::new(SyntheticRunner { heads_total: 2, head_dim: 8, vocab: 997 }, 8, 4);
    for i in 0..6u64 {
        let tenant = (i % 2) as usize;
        let prompt = corpus.make_request_tokens(&tok, tenant, 8, &mut rng);
        engine.submit(chunk_attention::workload::Request {
            id: i,
            arrival_s: 0.0,
            tenant,
            shared_tokens: corpus.tenants[tenant].system_tokens.len(),
            prompt,
            max_new_tokens: 4,
        });
    }
    let finished = engine.run_to_completion().unwrap();
    assert_eq!(finished.len(), 6);
    let stats = engine.stats();
    // 2 tenants × 2 repeat requests each reuse the tenant system prompt.
    assert!(
        stats.prefill_tokens_reused as usize >= 4 * 55,
        "reused {} tokens",
        stats.prefill_tokens_reused
    );
    engine.tree().check_invariants().unwrap();
}

#[test]
fn engine_sharing_stats_track_live_sequences() {
    let mut engine =
        Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 31 }, 4, 8);
    let sys: Vec<u32> = (0..32).collect();
    for i in 0..4u64 {
        let mut p = sys.clone();
        p.push(100 + i as u32);
        engine.submit(chunk_attention::workload::Request {
            id: i,
            arrival_s: 0.0,
            tenant: 0,
            shared_tokens: sys.len(),
            prompt: p,
            max_new_tokens: 64, // long enough that all 4 decode together
        });
    }
    // Step until all 4 admitted and a few decodes in.
    for _ in 0..6 {
        engine.step().unwrap();
    }
    let stats = engine.tree().sharing_stats();
    assert!(stats.sharing_ratio() > 0.5, "ratio {}", stats.sharing_ratio());
    // Every sequence still resolves its own dense KV.
    for i in 0..4u64 {
        let (_, _, tokens) = engine.tree().gather_dense(SeqId(i)).unwrap();
        assert_eq!(&tokens[..32], &sys[..]);
    }
}

#[test]
fn decode_steps_reuse_cached_context_until_topology_changes() {
    // chunk_size 16 and a 4-token prompt: after admission the sequence's
    // private tail chunk has room for every decoded token, so no decode
    // step changes the tree topology and the engine must serve every step
    // after the first from its cached context — without calling
    // `PrefixTree::context()` at all.
    let mut engine =
        Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 97 }, 16, 4);
    engine.submit(chunk_attention::workload::Request {
        id: 0,
        arrival_s: 0.0,
        tenant: 0,
        shared_tokens: 0,
        prompt: vec![1, 2, 3, 4],
        max_new_tokens: 8,
    });
    let finished = engine.run_to_completion().unwrap();
    assert_eq!(finished.len(), 1);
    let m = engine.metrics();
    // 7 decode steps total (prefill emits the first of 8 tokens): one
    // rebuild on the admission step, cache hits on all six others.
    assert_eq!(m.context_rebuilds, 1, "only the admission step rebuilds");
    assert_eq!(m.context_cache_hits, 6, "all topology-stable steps hit");
    // The tree's lazy-cache path was never touched: the engine keeps the
    // only context cache (via `context_fresh`), so cache-hit steps never
    // reach `PrefixTree::context()` at all.
    let (tree_rebuilds, tree_hits) = engine.tree().context_stats();
    assert_eq!((tree_rebuilds, tree_hits), (0, 0));
    // The counters are exported for e2e observability.
    let text = chunk_attention::metrics::render_exposition(m, "e2e");
    assert!(text.contains("e2e_context_rebuilds_total 1"), "{text}");
    assert!(text.contains("e2e_context_cache_hits_total 6"), "{text}");
}

#[test]
fn context_rebuilds_track_chunk_boundary_crossings() {
    // Tiny chunks (4 tokens) force periodic chunk-boundary crossings, so
    // some decode steps rebuild — but between boundaries the cache must
    // still serve hits, and rebuilds stay well below total steps.
    let mut engine =
        Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 97 }, 4, 4);
    for i in 0..3u64 {
        engine.submit(chunk_attention::workload::Request {
            id: i,
            arrival_s: 0.0,
            tenant: 0,
            shared_tokens: 0,
            prompt: vec![7, 8, 9, 10 + i as u32],
            max_new_tokens: 16,
        });
    }
    engine.run_to_completion().unwrap();
    let m = engine.metrics();
    let steps = engine.stats().decode_steps;
    assert_eq!(m.context_rebuilds + m.context_cache_hits, steps);
    assert!(m.context_cache_hits > 0, "steady-state steps must hit");
    assert!(
        m.context_rebuilds < steps,
        "rebuilds {} must not cover all {} steps",
        m.context_rebuilds,
        steps
    );
    assert!(m.context_hit_rate() > 0.5, "hit rate {}", m.context_hit_rate());
}

#[test]
fn chunked_prefill_4096_token_cold_prompt_never_starves_decode() {
    // The head-of-line acceptance scenario: one 4096-token cold prompt
    // arrives while decoders are active. With chunked prefill no single
    // engine step may spend more than the configured token budget, and
    // decode steps must keep advancing between prefill slices.
    let budget = 256u64;
    let mut engine =
        Engine::new(SyntheticRunner { heads_total: 2, head_dim: 8, vocab: 997 }, 32, 8);
    engine.set_chunked_prefill(64, budget as usize);
    for i in 0..2u64 {
        engine.submit(chunk_attention::workload::Request {
            id: i,
            arrival_s: 0.0,
            tenant: 0,
            shared_tokens: 0,
            prompt: vec![10 + i as u32, 2, 3, 4],
            max_new_tokens: 400, // stays active for the whole prefill
        });
    }
    engine.step().unwrap();
    assert_eq!(engine.scheduler().batch_size(), 2, "decoders active before the cold prompt");

    engine.submit(chunk_attention::workload::Request {
        id: 9,
        arrival_s: 0.0,
        tenant: 1,
        shared_tokens: 0,
        prompt: (100_000u32..104_096).collect(), // 4096 cold tokens
        max_new_tokens: 4,
    });
    let mut prev = engine.stats();
    let mut prefill_iters = 0u32;
    let mut decode_alongside = 0u32;
    let mut steps = 0u32;
    loop {
        engine.step().unwrap();
        steps += 1;
        let s = engine.stats();
        let spent = (s.prefill_tokens_computed - prev.prefill_tokens_computed)
            + (s.decoded_tokens - prev.decoded_tokens);
        assert!(spent <= budget, "engine step spent {spent} tokens, budget is {budget}");
        if s.prefill_chunks_total > prev.prefill_chunks_total {
            prefill_iters += 1;
            if s.decode_steps > prev.decode_steps {
                decode_alongside += 1;
            }
        }
        prev = s;
        if engine.scheduler().prefill_depth() == 0 {
            break;
        }
        assert!(steps < 100, "4096-token prefill never completed");
    }
    assert!(
        prefill_iters >= 2,
        "the 4096-token prefill must be split across engine iterations, saw {prefill_iters}"
    );
    assert!(
        decode_alongside >= 2,
        "decode must advance between prefill slices, saw {decode_alongside}"
    );
    // ~16 slices of 64 tokens fit a 254-token budget per step: the whole
    // prefill takes several iterations but far fewer than token count.
    assert!(engine.stats().prefill_chunks_total as usize >= 4096 / 256);
    engine.tree().check_invariants().unwrap();
    let finished = engine.run_to_completion().unwrap();
    assert_eq!(finished.len(), 3);
    assert_eq!(engine.tree().pool().in_use(), 0);
}

#[test]
fn simulator_and_engine_agree_on_scheduling_shape() {
    // The virtual-time simulator and the real engine share the Scheduler;
    // with the same trace they must admit the same peak batch.
    let trace = Trace::poisson_synthetic(
        &TraceConfig {
            rps: 1000.0, // effectively simultaneous arrival
            n_requests: 12,
            n_tenants: 2,
            tenant_skew: 0.0,
            query_tokens: 4,
            completion_tokens: 3,
            seed: 9,
        },
        16,
    );
    let sim = simulate(
        &SimConfig { max_batch: 8, ..SimConfig::new(SystemKind::ChunkLlama) },
        &ModelConfig::llama2_7b(),
        &HardwareModel::a100_80g(),
        &trace,
    );
    assert_eq!(sim.finished_requests, 12);
    assert_eq!(sim.peak_batch, 8);

    let mut engine =
        Engine::new(SyntheticRunner { heads_total: 2, head_dim: 4, vocab: 101 }, 8, 8);
    for r in &trace.requests {
        engine.submit(r.clone());
    }
    engine.run_to_completion().unwrap();
    assert_eq!(engine.scheduler().peak_batch(), 8);
}

#[test]
fn fig5_ordering_holds_in_simulation() {
    // At moderate load with a shared 1024-token prompt, ChunkLlama <
    // vLLM < TGI in normalized latency (Fig. 5's line ordering).
    let trace = Trace::poisson_synthetic(
        &TraceConfig {
            rps: 1.2,
            n_requests: 60,
            n_tenants: 1,
            tenant_skew: 0.0,
            query_tokens: 32,
            completion_tokens: 96,
            seed: 31,
        },
        1024,
    );
    let model = ModelConfig::llama2_7b();
    let hw = HardwareModel::a100_80g();
    let lat = |sys| {
        simulate(&SimConfig::new(sys), &model, &hw, &trace).normalized_latency_ms_per_tok
    };
    let chunk = lat(SystemKind::ChunkLlama);
    let vllm = lat(SystemKind::Vllm);
    let tgi = lat(SystemKind::Tgi);
    assert!(chunk < vllm, "chunk {chunk} < vllm {vllm}");
    assert!(vllm <= tgi * 1.05, "vllm {vllm} <= tgi {tgi}");
}
