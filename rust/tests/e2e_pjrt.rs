//! Integration: the full three-layer stack. Rust engine (L3) serves
//! requests whose forward passes run in the AOT-compiled JAX model (L2)
//! containing the Pallas TPP kernel (L1), all through PJRT.
//!
//! Requires `make artifacts`; tests self-skip when the directory is absent
//! so a fresh checkout still passes `cargo test`.

use std::path::PathBuf;

use chunk_attention::coordinator::Engine;
use chunk_attention::runtime::PjrtModel;
use chunk_attention::workload::Request;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn request(id: u64, prompt: Vec<u32>, completion: usize) -> Request {
    Request { id, arrival_s: 0.0, tenant: 0, prompt, shared_tokens: 0, max_new_tokens: completion }
}

#[test]
fn pjrt_kernel_artifact_matches_ref_numerics() {
    // The standalone L1 kernel artifact: execute with known inputs and
    // check against an in-process Rust oracle computation.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    use chunk_attention::runtime::{Manifest, PjrtRuntime};
    let manifest = Manifest::load(&dir).unwrap();
    let a = manifest.kernel_test_artifact().expect("kernel_test artifact").clone();
    // Shapes from aot.py KERNEL_TEST_SHAPE.
    let (b, h, c, d, m) = (4usize, 4usize, 16usize, 64usize, 8usize);

    let runtime = PjrtRuntime::cpu().unwrap();
    let exe = runtime.load_hlo_text(&dir.join(&a.file)).unwrap();

    let mut rng = chunk_attention::util::Pcg64::seeded(5);
    let mut q = vec![0.0f32; b * h * d];
    let mut k = vec![0.0f32; m * h * c * d];
    let mut v = vec![0.0f32; m * h * c * d];
    rng.fill_uniform_f32(&mut q, -1.0, 1.0);
    rng.fill_uniform_f32(&mut k, -1.0, 1.0);
    rng.fill_uniform_f32(&mut v, -1.0, 1.0);
    let starts = vec![0i32, 0, 2, 0, 1, 3, 0, 0];
    let ends = vec![4i32, 2, 4, 1, 3, 4, 0, 0];
    let lens = vec![16i32, 16, 8, 16, 5, 16, 0, 0];

    let ql = chunk_attention::runtime::pjrt::f32_literal(&q, &[b as i64, h as i64, d as i64]).unwrap();
    let kl = chunk_attention::runtime::pjrt::f32_literal(&k, &[m as i64, h as i64, c as i64, d as i64]).unwrap();
    let vl = chunk_attention::runtime::pjrt::f32_literal(&v, &[m as i64, h as i64, c as i64, d as i64]).unwrap();
    let sl = chunk_attention::runtime::pjrt::i32_literal(&starts, &[m as i64]).unwrap();
    let el = chunk_attention::runtime::pjrt::i32_literal(&ends, &[m as i64]).unwrap();
    let ll = chunk_attention::runtime::pjrt::i32_literal(&lens, &[m as i64]).unwrap();
    let out = runtime.execute(&exe, &[&ql, &kl, &vl, &sl, &el, &ll]).unwrap();
    let got = out[0].to_vec::<f32>().unwrap();
    assert_eq!(got.len(), b * h * d);

    // Oracle: per (row, head) dense softmax over visible chunk tokens.
    let scale = 1.0 / (d as f64).sqrt();
    for r in 0..b {
        for hh in 0..h {
            let qrow = &q[(r * h + hh) * d..(r * h + hh + 1) * d];
            let mut logits = Vec::new();
            let mut values: Vec<&[f32]> = Vec::new();
            for ci in 0..m {
                if (starts[ci] as usize) <= r && r < ends[ci] as usize {
                    for t in 0..lens[ci] as usize {
                        let base = ((ci * h + hh) * c + t) * d;
                        let krow = &k[base..base + d];
                        let s: f64 =
                            qrow.iter().zip(krow).map(|(a, b)| *a as f64 * *b as f64).sum();
                        logits.push(s * scale);
                        values.push(&v[base..base + d]);
                    }
                }
            }
            let base_out = (r * h + hh) * d;
            if logits.is_empty() {
                for i in 0..d {
                    assert_eq!(got[base_out + i], 0.0);
                }
                continue;
            }
            let mx = logits.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let e: Vec<f64> = logits.iter().map(|x| (x - mx).exp()).collect();
            let n: f64 = e.iter().sum();
            for i in 0..d {
                let expect: f64 =
                    e.iter().zip(&values).map(|(w, vr)| w * vr[i] as f64).sum::<f64>() / n;
                let gotv = got[base_out + i] as f64;
                assert!(
                    (gotv - expect).abs() < 1e-4,
                    "row {r} head {hh} dim {i}: {gotv} vs {expect}"
                );
            }
        }
    }
}

#[test]
fn pjrt_prefill_matches_pure_rust_reference_model() {
    // Three implementations of the same model must agree: the JAX-lowered
    // HLO through PJRT, the Pallas kernel inside it, and a from-scratch
    // Rust forward pass over the identical weights.bin.
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    use chunk_attention::model::ReferenceModel;
    let mut pjrt = PjrtModel::load(&dir).unwrap();
    let reference = ReferenceModel::load(pjrt.manifest()).unwrap();

    let tokens: Vec<u32> = vec![5, 99, 1023, 7, 444, 12, 900, 31];
    let (logits, k_rows, v_rows) = reference.prefill(&tokens);

    use chunk_attention::coordinator::ModelRunner;
    let out = pjrt.prefill(&tokens, 0, &[], &[], 0, true).unwrap();

    // Greedy next token must agree.
    let ref_argmax =
        (0..logits.len()).max_by(|&a, &b| logits[a].partial_cmp(&logits[b]).unwrap()).unwrap();
    assert_eq!(out.next_token, Some(ref_argmax as u32), "argmax disagreement");

    // K/V rows for every position must agree numerically.
    assert_eq!(out.k_rows.len(), tokens.len());
    for p in 0..tokens.len() {
        for (a, b) in out.k_rows[p].iter().zip(&k_rows[p]) {
            assert!((a - b).abs() < 5e-4, "k row {p}: {a} vs {b}");
        }
        for (a, b) in out.v_rows[p].iter().zip(&v_rows[p]) {
            assert!((a - b).abs() < 5e-4, "v row {p}: {a} vs {b}");
        }
    }
}

#[test]
fn engine_serves_batched_requests_through_pjrt() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    let model = PjrtModel::load(&dir).expect("load artifacts");
    let chunk_size = model.chunk_size();
    let max_batch = model.max_batch().min(4);
    let mut engine = Engine::new(model, chunk_size, max_batch);

    // Three requests sharing a 24-token system prompt + one disjoint.
    let sys: Vec<u32> = (100..124).collect();
    for i in 0..3u64 {
        let mut p = sys.clone();
        p.extend([200 + i as u32 * 7, 300 + i as u32]);
        engine.submit(request(i, p, 6));
    }
    engine.submit(request(3, (500..516).collect(), 6));

    let finished = engine.run_to_completion().expect("serve");
    assert_eq!(finished.len(), 4);
    for i in 0..4u64 {
        let completion = engine.completion_of(i).unwrap();
        assert_eq!(completion.len(), 6);
        assert!(completion.iter().all(|&t| (t as usize) < 2048), "tokens in vocab");
    }
    // Prefix reuse happened: requests 1 and 2 reused the system prompt.
    let stats = engine.stats();
    assert!(stats.prefill_tokens_reused >= 2 * sys.len() as u64);
    assert_eq!(engine.tree().pool().in_use(), 0, "cache drained");
}

#[test]
fn pjrt_decode_is_deterministic_and_batch_invariant() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: run `make artifacts`");
        return;
    };
    // Completion of a prompt must not depend on what else is in the batch
    // (greedy decoding, per-sequence attention isolation).
    let run = |extra: bool| {
        let model = PjrtModel::load(&dir).unwrap();
        let chunk_size = model.chunk_size();
        let mut engine = Engine::new(model, chunk_size, 4);
        engine.submit(request(0, (40..56).collect(), 5));
        if extra {
            engine.submit(request(1, (60..70).collect(), 5));
            engine.submit(request(2, (40..50).collect(), 5));
        }
        engine.run_to_completion().unwrap();
        engine.completion_of(0).unwrap().to_vec()
    };
    let solo = run(false);
    let batched = run(true);
    assert_eq!(solo, batched, "batching must not change greedy output");
}
