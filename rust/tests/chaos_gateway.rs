//! Socket-level chaos tests: armed failpoints against a live gateway.
//!
//! Each test arms a failpoint profile (process-global state), drives real
//! TCP clients, and asserts the gateway's degradation ladder from the
//! outside: transient errors retry, panics quarantine only the implicated
//! stream, deadlines release residency, the watchdog degrades `/healthz`,
//! and — above all — the process keeps serving. Because the failpoint
//! registry is process-global and Rust tests share one process, every test
//! serializes on [`chaos_guard`] and disarms on every exit path via the
//! [`Disarm`] drop guard.

use chunk_attention::coordinator::engine::testing::SyntheticRunner;
use chunk_attention::coordinator::Engine;
use chunk_attention::server::client::{self, StreamEvent};
use chunk_attention::server::{gauge_value, labeled_gauge_value, Gateway, GatewayConfig};
use chunk_attention::util::failpoint;
use chunk_attention::util::json::Json;
use std::io::Write;
use std::net::TcpStream;
use std::sync::{mpsc, Mutex, MutexGuard, OnceLock};
use std::thread;
use std::time::{Duration, Instant};

/// Serialize every test in this binary: failpoints are process-global.
fn chaos_guard() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

/// Disarm every failpoint when the test exits, pass or panic.
struct Disarm;
impl Drop for Disarm {
    fn drop(&mut self) {
        failpoint::disarm_all();
    }
}

/// Hard per-test timeout so a wedged gateway fails fast in CI.
fn with_watchdog<T: Send + 'static>(
    secs: u64,
    name: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    let worker = thread::spawn(move || {
        let result = f();
        let _ = tx.send(());
        result
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("test {name} exceeded its {secs}s watchdog (hung gateway?)")
        }
        Ok(()) | Err(mpsc::RecvTimeoutError::Disconnected) => match worker.join() {
            Ok(result) => result,
            Err(panic) => std::panic::resume_unwind(panic),
        },
    }
}

fn engine(chunk: usize, max_batch: usize) -> Engine<SyntheticRunner> {
    Engine::new(SyntheticRunner { heads_total: 2, head_dim: 8, vocab: 32000 }, chunk, max_batch)
}

fn token_body(tokens: &[u32], shared: usize, max_new: usize) -> Json {
    let mut body = Json::obj();
    body.set("tokens", Json::Arr(tokens.iter().map(|&t| Json::Num(t as f64)).collect()));
    body.set("shared_tokens", shared).set("max_new_tokens", max_new);
    body
}

fn scrape(addr: &str) -> String {
    let resp = client::get(addr, "/metrics", Duration::from_secs(10)).expect("scrape /metrics");
    assert_eq!(resp.status, 200);
    resp.body
}

/// How one streamed request ended, as the client saw it.
#[derive(Debug, PartialEq)]
enum Outcome {
    /// Stream completed; carries the tokens in arrival order.
    Done(Vec<u32>),
    /// Terminal SSE error, or a pre-stream HTTP 500; carries the message.
    Failed(String),
    /// Terminal SSE timeout, or a pre-stream HTTP 504.
    TimedOut(Vec<u32>),
    /// The stream ended with no terminal event — a bug this suite exists
    /// to catch.
    SilentEof,
}

/// Issue one request and drive its stream to a terminal outcome.
fn drive(addr: &str, body: &Json) -> Outcome {
    let mut stream = client::generate(addr, body, Duration::from_secs(30)).expect("connect");
    match stream.status() {
        200 => {}
        500 => return Outcome::Failed(stream.error_body.clone()),
        504 => return Outcome::TimedOut(Vec::new()),
        other => panic!("unexpected HTTP status {other}: {}", stream.error_body),
    }
    let mut tokens = Vec::new();
    loop {
        match stream.next_event().expect("read SSE event") {
            Some(StreamEvent::Token { index, token }) => {
                assert_eq!(index, tokens.len(), "tokens arrive in order");
                tokens.push(token);
            }
            Some(StreamEvent::Done { completion_tokens }) => {
                assert_eq!(completion_tokens, tokens.len());
                return Outcome::Done(tokens);
            }
            Some(StreamEvent::Error { message }) => return Outcome::Failed(message),
            Some(StreamEvent::Timeout) => return Outcome::TimedOut(tokens),
            None => return Outcome::SilentEof,
        }
    }
}

/// Poll `/metrics` until `pred` holds or the timeout expires; returns the
/// last scraped document either way.
fn poll_metrics(addr: &str, timeout: Duration, pred: impl Fn(&str) -> bool) -> String {
    let t0 = Instant::now();
    loop {
        let doc = scrape(addr);
        if pred(&doc) || t0.elapsed() > timeout {
            return doc;
        }
        thread::sleep(Duration::from_millis(25));
    }
}

#[test]
fn disarmed_failpoints_are_a_noop() {
    let _guard = chaos_guard();
    let _disarm = Disarm;
    failpoint::disarm_all();
    with_watchdog(30, "disarmed_noop", || {
        let gw = Gateway::start(engine(16, 4), GatewayConfig::default()).unwrap();
        let addr = gw.addr().to_string();
        match drive(&addr, &token_body(&[1, 2, 3, 4], 0, 8)) {
            Outcome::Done(tokens) => assert_eq!(tokens.len(), 8),
            other => panic!("clean request must complete, got {other:?}"),
        }
        let doc = scrape(&addr);
        for counter in [
            "engine_panics_total",
            "engine_rebuilds_total",
            "requests_timed_out_total",
            "step_retries_total",
            "watchdog_stalls_total",
        ] {
            assert_eq!(gauge_value(&doc, counter), Some(0.0), "{counter} must be 0 when disarmed");
        }
        assert_eq!(gauge_value(&doc, "tree_invariants_ok"), Some(1.0));
        gw.shutdown().unwrap();
    });
}

#[test]
fn transient_step_error_is_retried_and_the_request_completes() {
    let _guard = chaos_guard();
    let _disarm = Disarm;
    with_watchdog(30, "transient_retry", || {
        let gw = Gateway::start(engine(16, 4), GatewayConfig::default()).unwrap();
        let addr = gw.addr().to_string();
        failpoint::configure("engine.prefill", "1*err(transient glitch)").unwrap();
        match drive(&addr, &token_body(&[10, 20, 30], 0, 6)) {
            Outcome::Done(tokens) => assert_eq!(tokens.len(), 6),
            other => panic!("one transient error must be absorbed by retry, got {other:?}"),
        }
        let doc = scrape(&addr);
        assert!(gauge_value(&doc, "step_retries_total") >= Some(1.0), "retry counter advanced");
        assert_eq!(gauge_value(&doc, "engine_panics_total"), Some(0.0));
        assert_eq!(labeled_gauge_value(&doc, "requests_failed_total", "reason", "error"), Some(0.0));
        gw.shutdown().unwrap();
    });
}

#[test]
fn persistent_step_errors_fail_only_the_victim_after_retries() {
    let _guard = chaos_guard();
    let _disarm = Disarm;
    with_watchdog(30, "persistent_error", || {
        let gw = Gateway::start(engine(16, 4), GatewayConfig::default()).unwrap();
        let addr = gw.addr().to_string();
        // step_retry_max defaults to 3: the 4th consecutive failure
        // exhausts the budget and quarantines the attributed sequence.
        failpoint::configure("engine.prefill", "4*err(persistent failure)").unwrap();
        match drive(&addr, &token_body(&[40, 50, 60], 0, 6)) {
            Outcome::Failed(msg) => {
                assert!(msg.contains("failpoint"), "error carries the injected cause: {msg}")
            }
            other => panic!("persistent errors must fail the request, got {other:?}"),
        }
        let doc = scrape(&addr);
        assert_eq!(
            labeled_gauge_value(&doc, "requests_failed_total", "reason", "error"),
            Some(1.0)
        );
        assert_eq!(gauge_value(&doc, "tree_invariants_ok"), Some(1.0));
        // The site is exhausted; the gateway keeps serving.
        match drive(&addr, &token_body(&[40, 50, 60], 0, 6)) {
            Outcome::Done(tokens) => assert_eq!(tokens.len(), 6),
            other => panic!("gateway must keep serving after quarantine, got {other:?}"),
        }
        gw.shutdown().unwrap();
    });
}

#[test]
fn stepper_panic_mid_decode_quarantines_only_the_victim() {
    let _guard = chaos_guard();
    let _disarm = Disarm;
    with_watchdog(60, "panic_quarantine", || {
        let cfg = GatewayConfig {
            decode_interval: Duration::from_micros(500),
            ..GatewayConfig::default()
        };
        let gw = Gateway::start(engine(64, 8), cfg).unwrap();
        let addr = gw.addr().to_string();
        let system_prompt: Vec<u32> = (0..1024).collect();

        // Panic exactly once, a few decode-append evaluations in, so the
        // blast hits one sequence mid-stream while others share its prefix.
        failpoint::configure("engine.decode.append", "1*panic(injected decode panic)@10")
            .unwrap();

        let mut clients = Vec::new();
        for c in 0..4u32 {
            let addr = addr.clone();
            let mut prompt = system_prompt.clone();
            prompt.extend([5000 + c, 6000 + c]);
            clients.push(thread::spawn(move || {
                (prompt.clone(), drive(&addr, &token_body(&prompt, 1024, 8)))
            }));
        }
        let outcomes: Vec<(Vec<u32>, Outcome)> =
            clients.into_iter().map(|c| c.join().unwrap()).collect();

        let mut survivors = Vec::new();
        let mut victims = 0usize;
        for (prompt, outcome) in outcomes {
            match outcome {
                Outcome::Done(tokens) => {
                    assert_eq!(tokens.len(), 8, "survivors stream their full completion");
                    survivors.push((prompt, tokens));
                }
                Outcome::Failed(msg) => {
                    assert!(
                        msg.contains("failpoint") || msg.contains("panic"),
                        "victim's terminal error names the injected cause: {msg}"
                    );
                    victims += 1;
                }
                other => panic!("no stream may end without a terminal event: {other:?}"),
            }
        }
        assert_eq!(victims, 1, "exactly the implicated sequence is quarantined");
        assert_eq!(survivors.len(), 3, "every other shared-prefix stream completes");

        // Correctness, not just liveness: the synthetic runner is a pure
        // function of (token, position), so a clean replay of a survivor's
        // prompt must reproduce its exact tokens.
        let (prompt, tokens) = &survivors[0];
        match drive(&addr, &token_body(prompt, 1024, 8)) {
            Outcome::Done(replay) => {
                assert_eq!(&replay, tokens, "survivor tokens match a clean replay")
            }
            other => panic!("replay must complete, got {other:?}"),
        }

        let health = client::get(&addr, "/healthz", Duration::from_secs(5)).unwrap();
        assert_eq!(health.status, 200, "the process never exits: {}", health.body);
        let doc = scrape(&addr);
        assert_eq!(gauge_value(&doc, "engine_panics_total"), Some(1.0));
        assert_eq!(gauge_value(&doc, "engine_rebuilds_total"), Some(0.0));
        assert_eq!(gauge_value(&doc, "tree_invariants_ok"), Some(1.0));
        assert_eq!(
            labeled_gauge_value(&doc, "requests_failed_total", "reason", "panic"),
            Some(1.0)
        );
        gw.shutdown().unwrap();
    });
}

#[test]
fn deadline_is_enforced_and_residency_released() {
    let _guard = chaos_guard();
    let _disarm = Disarm;
    failpoint::disarm_all();
    with_watchdog(30, "deadline", || {
        let cfg = GatewayConfig {
            decode_interval: Duration::from_millis(5),
            ..GatewayConfig::default()
        };
        let gw = Gateway::start(engine(16, 4), cfg).unwrap();
        let addr = gw.addr().to_string();
        let baseline = gauge_value(&scrape(&addr), "kv_bytes_in_use").unwrap();

        // 500-token budget at 5ms/step cannot finish inside 150ms.
        let mut body = token_body(&[7, 8, 9, 10], 0, 500);
        body.set("deadline_ms", 150u64);
        match drive(&addr, &body) {
            Outcome::TimedOut(tokens) => {
                assert!(
                    tokens.len() < 500,
                    "deadline must interrupt the stream, not let it finish"
                );
            }
            other => panic!("expected a terminal timeout, got {other:?}"),
        }
        let doc = poll_metrics(&addr, Duration::from_secs(5), |doc| {
            gauge_value(doc, "kv_bytes_in_use") == Some(baseline)
        });
        assert_eq!(
            gauge_value(&doc, "kv_bytes_in_use"),
            Some(baseline),
            "timed-out request's private chunks return to the pool"
        );
        assert_eq!(gauge_value(&doc, "requests_timed_out_total"), Some(1.0));
        assert_eq!(gauge_value(&doc, "tree_invariants_ok"), Some(1.0));
        gw.shutdown().unwrap();
    });
}

#[test]
fn client_disconnect_races_injected_prefill_error_without_leaking_residency() {
    let _guard = chaos_guard();
    let _disarm = Disarm;
    with_watchdog(60, "disconnect_race", || {
        // Chunked prefill stretches a 512-token prompt over ~16 paced
        // steps (~160ms) so both the disconnect (at ~100ms) and the
        // injected runner error (slice 5) land mid-prefill.
        let cfg = GatewayConfig {
            prefill_chunk_tokens: 32,
            step_token_budget: 48,
            decode_interval: Duration::from_millis(10),
            ..GatewayConfig::default()
        };
        let gw = Gateway::start(engine(32, 4), cfg).unwrap();
        let addr = gw.addr().to_string();
        let baseline = gauge_value(&scrape(&addr), "kv_bytes_in_use").unwrap();
        failpoint::configure("engine.prefill", "1*err(mid-prefill glitch)@4").unwrap();

        // Hand-rolled request so the socket can be dropped before the
        // response head exists (the prompt is still prefilling).
        let prompt: Vec<u32> = (0..512).collect();
        let payload = token_body(&prompt, 0, 2000).to_string();
        {
            let mut sock = TcpStream::connect(&addr).unwrap();
            write!(
                sock,
                "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
                 Content-Length: {}\r\nConnection: close\r\n\r\n{payload}",
                payload.len()
            )
            .unwrap();
            sock.flush().unwrap();
            thread::sleep(Duration::from_millis(100));
            // Drop: the handler's liveness probe sees the FIN and cancels.
        }

        let doc = poll_metrics(&addr, Duration::from_secs(10), |doc| {
            gauge_value(doc, "kv_bytes_in_use") == Some(baseline)
                && gauge_value(doc, "live_streams") == Some(0.0)
        });
        assert_eq!(
            gauge_value(&doc, "kv_bytes_in_use"),
            Some(baseline),
            "abandoned mid-prefill request must not leak residency"
        );
        assert_eq!(gauge_value(&doc, "tree_invariants_ok"), Some(1.0));
        // The gateway still serves after the race.
        match drive(&addr, &token_body(&[1, 2, 3], 0, 4)) {
            Outcome::Done(tokens) => assert_eq!(tokens.len(), 4),
            other => panic!("gateway must keep serving, got {other:?}"),
        }
        gw.shutdown().unwrap();
    });
}

#[test]
fn watchdog_degrades_healthz_during_stalls_and_recovers() {
    let _guard = chaos_guard();
    let _disarm = Disarm;
    with_watchdog(60, "watchdog", || {
        let cfg = GatewayConfig {
            watchdog_stall: Duration::from_millis(100),
            decode_interval: Duration::from_millis(1),
            ..GatewayConfig::default()
        };
        let gw = Gateway::start(engine(16, 4), cfg).unwrap();
        let addr = gw.addr().to_string();
        // Each armed step blocks 300ms — three stall windows well past the
        // 100ms watchdog bound, then the site exhausts and steps run free.
        failpoint::configure("engine.step", "3*sleep(300)").unwrap();

        // Keep the stepper busy while probing health from outside.
        let bg_addr = addr.clone();
        let bg = thread::spawn(move || drive(&bg_addr, &token_body(&[1, 2, 3], 0, 400)));

        let t0 = Instant::now();
        let mut saw_degraded = false;
        while t0.elapsed() < Duration::from_secs(10) {
            if let Ok(resp) = client::get(&addr, "/healthz", Duration::from_secs(2)) {
                if resp.status == 503 {
                    assert!(resp.body.contains("degraded"), "{}", resp.body);
                    assert!(resp.retry_after.is_some(), "degraded health advertises Retry-After");
                    saw_degraded = true;
                    break;
                }
            }
            thread::sleep(Duration::from_millis(10));
        }
        assert!(saw_degraded, "watchdog must flip /healthz to 503 during the stall");

        // After the sleeps exhaust, the stepper beats again and health
        // recovers without a restart.
        let t0 = Instant::now();
        let mut recovered = false;
        while t0.elapsed() < Duration::from_secs(10) {
            if let Ok(resp) = client::get(&addr, "/healthz", Duration::from_secs(2)) {
                if resp.status == 200 {
                    recovered = true;
                    break;
                }
            }
            thread::sleep(Duration::from_millis(10));
        }
        assert!(recovered, "healthz must recover once the stall clears");
        match bg.join().unwrap() {
            Outcome::Done(tokens) => assert_eq!(tokens.len(), 400),
            other => panic!("the stalled request still completes, got {other:?}"),
        }
        let doc = scrape(&addr);
        assert!(gauge_value(&doc, "watchdog_stalls_total") >= Some(1.0));
        assert_eq!(gauge_value(&doc, "engine_panics_total"), Some(0.0));
        gw.shutdown().unwrap();
    });
}

#[test]
fn every_injected_failure_path_ends_with_a_terminal_event() {
    let _guard = chaos_guard();
    let _disarm = Disarm;
    with_watchdog(90, "terminal_events", || {
        // (profile to arm, request deadline) — one gateway per scenario so
        // each failure lands on a fresh engine.
        let scenarios: [(&str, Option<u64>); 3] = [
            ("engine.decode.append=1*panic(boom)@2", None),
            ("engine.prefill=4*err(persistent failure)", None),
            ("", Some(100)),
        ];
        for (profile, deadline_ms) in scenarios {
            failpoint::disarm_all();
            let cfg = GatewayConfig {
                decode_interval: Duration::from_millis(2),
                ..GatewayConfig::default()
            };
            let gw = Gateway::start(engine(16, 4), cfg).unwrap();
            let addr = gw.addr().to_string();
            failpoint::configure_list(profile).unwrap();
            let mut body = token_body(&[11, 22, 33], 0, 300);
            if let Some(ms) = deadline_ms {
                body.set("deadline_ms", ms);
            }
            let outcome = drive(&addr, &body);
            assert!(
                outcome != Outcome::SilentEof,
                "stream under profile {profile:?} ended without a terminal event"
            );
            match (deadline_ms, &outcome) {
                (Some(_), Outcome::TimedOut(_)) => {}
                (Some(_), other) => panic!("deadline scenario must time out, got {other:?}"),
                (None, Outcome::Failed(_)) => {}
                (None, other) => panic!("failure profile {profile:?} must fail, got {other:?}"),
            }
            failpoint::disarm_all();
            gw.shutdown().unwrap();
        }
    });
}
