"""L1 correctness: the Pallas TPP kernel against the pure-jnp oracle.

Hypothesis sweeps shapes and randomly structured tree contexts (including
degenerate intervals, empty rows, padding chunks, and partial fills).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import chunk_attn, ref


def make_context(rng, b, m, c):
    """Random (starts, ends, lens): arbitrary intervals, some empty."""
    starts = rng.integers(0, b, size=m).astype(np.int32)
    widths = rng.integers(0, b + 1, size=m).astype(np.int32)
    ends = np.minimum(starts + widths, b).astype(np.int32)
    lens = rng.integers(0, c + 1, size=m).astype(np.int32)
    return jnp.asarray(starts), jnp.asarray(ends), jnp.asarray(lens)


def rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 6),
    h=st.integers(1, 4),
    c=st.sampled_from([2, 4, 8]),
    d=st.sampled_from([4, 8, 16]),
    m=st.integers(1, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_tpp_matches_ref_random_contexts(b, h, c, d, m, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, (b, h, d))
    k = rand(rng, (m, h, c, d))
    v = rand(rng, (m, h, c, d))
    starts, ends, lens = make_context(rng, b, m, c)
    expect = ref.ref_attention(q, k, v, starts, ends, lens)
    got = chunk_attn.tpp_attention(q, k, v, starts, ends, lens)
    np.testing.assert_allclose(got, expect, rtol=2e-5, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_partials_match_ref_partials(seed):
    rng = np.random.default_rng(seed)
    b, h, c, d, m = 4, 2, 4, 8, 5
    q = rand(rng, (b, h, d))
    k = rand(rng, (m, h, c, d))
    v = rand(rng, (m, h, c, d))
    starts, ends, lens = make_context(rng, b, m, c)
    eo, em, en = ref.ref_attention_partials(q, k, v, starts, ends, lens)
    go, gm, gn = chunk_attn.tpp_attention_partials(q, k, v, starts, ends, lens)
    # Finalised outputs must agree even where the (m, n) decomposition is
    # only defined up to rescaling; and m/n themselves agree here because
    # both use the running-max convention.
    np.testing.assert_allclose(chunk_attn.finalize(go, gn), chunk_attn.finalize(eo, en), rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(gn, en, rtol=2e-4, atol=2e-5)


def test_empty_rows_produce_zeros():
    b, h, c, d, m = 3, 2, 4, 8, 2
    rng = np.random.default_rng(0)
    q = rand(rng, (b, h, d))
    k = rand(rng, (m, h, c, d))
    v = rand(rng, (m, h, c, d))
    # Row 2 is covered by no chunk.
    starts = jnp.asarray([0, 0], jnp.int32)
    ends = jnp.asarray([2, 1], jnp.int32)
    lens = jnp.asarray([4, 2], jnp.int32)
    out = chunk_attn.tpp_attention(q, k, v, starts, ends, lens)
    np.testing.assert_allclose(out[2], np.zeros((h, d)), atol=0)
    assert not np.any(np.isnan(np.asarray(out)))


def test_all_padding_chunks():
    b, h, c, d, m = 2, 1, 4, 4, 3
    rng = np.random.default_rng(1)
    q = rand(rng, (b, h, d))
    k = rand(rng, (m, h, c, d))
    v = rand(rng, (m, h, c, d))
    zeros = jnp.zeros((m,), jnp.int32)
    out = chunk_attn.tpp_attention(q, k, v, zeros, zeros, zeros)
    np.testing.assert_allclose(out, np.zeros((b, h, d)), atol=0)


def test_chunk_order_invariance():
    """Online-softmax merging must be order-independent (§3.2)."""
    rng = np.random.default_rng(7)
    b, h, c, d, m = 4, 2, 4, 8, 6
    q = rand(rng, (b, h, d))
    k = rand(rng, (m, h, c, d))
    v = rand(rng, (m, h, c, d))
    starts, ends, lens = make_context(rng, b, m, c)
    out = chunk_attn.tpp_attention(q, k, v, starts, ends, lens)
    perm = rng.permutation(m)
    out_p = chunk_attn.tpp_attention(q, k[perm], v[perm], starts[perm], ends[perm], lens[perm])
    np.testing.assert_allclose(out, out_p, rtol=2e-5, atol=2e-5)


def test_merge_fresh_row_equals_inclusion():
    """Attending chunks + fresh row == attending an extended context."""
    rng = np.random.default_rng(9)
    b, h, c, d = 3, 2, 4, 8
    q = rand(rng, (b, h, d))
    k = rand(rng, (2, h, c, d))
    v = rand(rng, (2, h, c, d))
    starts = jnp.asarray([0, 1], jnp.int32)
    ends = jnp.asarray([3, 3], jnp.int32)
    lens = jnp.asarray([4, 3], jnp.int32)
    k_new = rand(rng, (b, h, d))
    v_new = rand(rng, (b, h, d))

    o, m, n = chunk_attn.tpp_attention_partials(q, k, v, starts, ends, lens)
    o, m, n = chunk_attn.merge_fresh_row(q, k_new, v_new, o, m, n)
    got = chunk_attn.finalize(o, n)

    # Reference: give each row its own extra chunk holding just its row.
    k_ext = jnp.zeros((2 + b, h, c, d), jnp.float32)
    v_ext = jnp.zeros_like(k_ext)
    k_ext = k_ext.at[:2].set(k).at[2:, :, 0].set(k_new.transpose(0, 1, 2))
    v_ext = v_ext.at[:2].set(v).at[2:, :, 0].set(v_new.transpose(0, 1, 2))
    starts_ext = jnp.concatenate([starts, jnp.arange(b, dtype=jnp.int32)])
    ends_ext = jnp.concatenate([ends, jnp.arange(1, b + 1, dtype=jnp.int32)])
    lens_ext = jnp.concatenate([lens, jnp.ones((b,), jnp.int32)])
    expect = ref.ref_attention(q, k_ext, v_ext, starts_ext, ends_ext, lens_ext)
    np.testing.assert_allclose(got, expect, rtol=2e-5, atol=2e-5)


def test_extreme_logits_stay_finite():
    b, h, c, d, m = 2, 1, 2, 4, 2
    q = jnp.full((b, h, d), 50.0, jnp.float32)
    k = jnp.full((m, h, c, d), 50.0, jnp.float32)
    v = jnp.asarray(np.arange(m * h * c * d).reshape(m, h, c, d), jnp.float32)
    starts = jnp.asarray([0, 0], jnp.int32)
    ends = jnp.asarray([2, 2], jnp.int32)
    lens = jnp.asarray([2, 2], jnp.int32)
    out = np.asarray(chunk_attn.tpp_attention(q, k, v, starts, ends, lens))
    assert np.all(np.isfinite(out))


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_paper_shape_smoke(dtype):
    """One paper-sized call: c=64, d=128, h=4 (subset), b=8."""
    rng = np.random.default_rng(3)
    b, h, c, d, m = 8, 4, 64, 128, 6
    q = rand(rng, (b, h, d), 0.1).astype(dtype)
    k = rand(rng, (m, h, c, d), 0.1).astype(dtype)
    v = rand(rng, (m, h, c, d), 0.1).astype(dtype)
    starts = jnp.asarray([0, 0, 0, 2, 4, 6], jnp.int32)
    ends = jnp.asarray([8, 4, 2, 4, 6, 8], jnp.int32)
    lens = jnp.asarray([64, 64, 32, 64, 64, 17], jnp.int32)
    expect = ref.ref_attention(q, k, v, starts, ends, lens)
    got = chunk_attn.tpp_attention(q, k, v, starts, ends, lens)
    np.testing.assert_allclose(got, expect, rtol=1e-4, atol=1e-4)
