"""L2 correctness: the mini model's decode path over chunked KV must agree
with dense computation, and prefill→decode must chain consistently."""

import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import chunk_attn

CFG = model.Config(n_layers=2, d_model=64, heads=2, head_dim=16, ffn_dim=96, vocab=101)


def chunks_from_rows(k_rows, v_rows, b_rows, c, m_pad):
    """Pack per-position KV rows [n, H, d] of ONE sequence into chunk
    tensors covering row interval [0, b_rows)."""
    n, H, d = k_rows.shape
    m = -(-n // c)
    kc = np.zeros((m_pad, H, c, d), np.float32)
    vc = np.zeros((m_pad, H, c, d), np.float32)
    lens = np.zeros((m_pad,), np.int32)
    starts = np.zeros((m_pad,), np.int32)
    ends = np.zeros((m_pad,), np.int32)
    for i in range(m):
        take = min(c, n - i * c)
        kc[i, :, :take] = np.transpose(k_rows[i * c : i * c + take], (1, 0, 2))
        vc[i, :, :take] = np.transpose(v_rows[i * c : i * c + take], (1, 0, 2))
        lens[i] = take
        starts[i] = 0
        ends[i] = b_rows
    return (jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(starts), jnp.asarray(ends), jnp.asarray(lens))


def test_prefill_then_decode_matches_longer_prefill():
    """Prefill [t0..t4] then decode t5 over its chunked KV must produce the
    same logits as prefilling [t0..t5] directly."""
    w = model.init_weights(CFG, seed=1)
    P, N = 16, 8
    tokens = np.array([5, 9, 12, 33, 47, 61], np.int32)

    def run_prefill(toks):
        padded = np.zeros((P,), np.int32)
        padded[: len(toks)] = toks
        pk = jnp.zeros((CFG.heads_total, N, CFG.head_dim), jnp.float32)
        return model.prefill_jit(
            CFG, w, jnp.asarray(padded), jnp.int32(len(toks)), pk, pk, jnp.int32(0)
        )

    logits_full, _, _ = run_prefill(tokens)

    logits_5, k5, v5 = run_prefill(tokens[:5])
    # Decode token t5 at position 5 with the prefilled KV as chunks.
    c, m_pad = 4, 6
    kc, vc, starts, ends, lens = chunks_from_rows(
        np.asarray(k5)[:5], np.asarray(v5)[:5], b_rows=1, c=c, m_pad=m_pad
    )
    logits_dec, _, _ = model.decode_step_jit(
        CFG,
        w,
        jnp.asarray([tokens[5]], jnp.int32),
        jnp.asarray([5], jnp.int32),
        kc,
        vc,
        starts,
        ends,
        lens,
    )
    np.testing.assert_allclose(np.asarray(logits_dec[0]), np.asarray(logits_full), rtol=2e-4, atol=2e-4)
    assert int(jnp.argmax(logits_dec[0])) == int(jnp.argmax(logits_full))


def test_decode_batch_rows_are_independent():
    """Each row's output depends only on its own chunks and token."""
    w = model.init_weights(CFG, seed=2)
    rng = np.random.default_rng(0)
    H, d, c, m = CFG.heads_total, CFG.head_dim, 4, 4
    kc = jnp.asarray(rng.normal(size=(m, H, c, d)) * 0.1, jnp.float32)
    vc = jnp.asarray(rng.normal(size=(m, H, c, d)) * 0.1, jnp.float32)
    lens = jnp.asarray([4, 4, 3, 2], jnp.int32)
    # Batch of 2: row 0 owns chunks 0,1; row 1 owns chunks 2,3.
    starts = jnp.asarray([0, 0, 1, 1], jnp.int32)
    ends = jnp.asarray([1, 1, 2, 2], jnp.int32)
    toks = jnp.asarray([7, 21], jnp.int32)
    pos = jnp.asarray([8, 7], jnp.int32)
    logits, _, _ = model.decode_step_jit(CFG, w, toks, pos, kc, vc, starts, ends, lens)

    # Row 0 solo with only its chunks visible.
    starts0 = jnp.asarray([0, 0, 9, 9], jnp.int32)
    ends0 = jnp.asarray([1, 1, 9, 9], jnp.int32)
    logits0, _, _ = model.decode_step_jit(
        CFG, w, toks[:1], pos[:1], kc, vc, starts0, ends0, lens
    )
    np.testing.assert_allclose(np.asarray(logits[0]), np.asarray(logits0[0]), rtol=2e-4, atol=2e-4)


def test_decode_returns_appendable_kv():
    """The returned fresh K/V rows, appended as a new chunk, must make the
    next decode step equal a two-token dense decode."""
    w = model.init_weights(CFG, seed=3)
    P, N = 8, 4
    prompt = np.array([3, 11, 19], np.int32)
    padded = np.zeros((P,), np.int32)
    padded[: len(prompt)] = prompt
    pk = jnp.zeros((CFG.heads_total, N, CFG.head_dim), jnp.float32)
    logits_p, kP, vP = model.prefill_jit(
        CFG, w, jnp.asarray(padded), jnp.int32(len(prompt)), pk, pk, jnp.int32(0)
    )
    t3 = int(jnp.argmax(logits_p))

    c, m_pad = 4, 4
    kc, vc, starts, ends, lens = chunks_from_rows(
        np.asarray(kP)[:3], np.asarray(vP)[:3], 1, c, m_pad
    )
    logits_d, k_new, v_new = model.decode_step_jit(
        CFG, w, jnp.asarray([t3], jnp.int32), jnp.asarray([3], jnp.int32), kc, vc, starts, ends, lens
    )
    t4 = int(jnp.argmax(logits_d[0]))

    # Compare with prefilling [prompt, t3] in one go.
    ext = np.zeros((P,), np.int32)
    ext[:4] = list(prompt) + [t3]
    logits_full, _, _ = model.prefill_jit(
        CFG, w, jnp.asarray(ext), jnp.int32(4), pk, pk, jnp.int32(0)
    )
    assert int(jnp.argmax(logits_full)) == t4
    np.testing.assert_allclose(np.asarray(logits_d[0]), np.asarray(logits_full), rtol=3e-4, atol=3e-4)
    assert np.asarray(k_new).shape == (1, CFG.heads_total, CFG.head_dim)
    assert np.asarray(v_new).shape == (1, CFG.heads_total, CFG.head_dim)


def test_prefill_uses_cached_prefix():
    """Prefilling a suffix on top of a cached prefix must equal prefilling
    the full sequence — the §3.2 prefix-lookup path."""
    w = model.init_weights(CFG, seed=4)
    P, N = 8, 8
    full = np.array([2, 4, 6, 8, 10, 12], np.int32)
    split = 4

    padded = np.zeros((P,), np.int32)
    padded[: len(full)] = full
    pk0 = jnp.zeros((CFG.heads_total, N, CFG.head_dim), jnp.float32)
    logits_full, k_full, v_full = model.prefill_jit(
        CFG, w, jnp.asarray(padded), jnp.int32(len(full)), pk0, pk0, jnp.int32(0)
    )

    # Cached prefix KV: rows [0, split) transposed to [H, N, d] padding.
    pk = np.zeros((CFG.heads_total, N, CFG.head_dim), np.float32)
    pv = np.zeros_like(pk)
    pk[:, :split] = np.transpose(np.asarray(k_full)[:split], (1, 0, 2))
    pv[:, :split] = np.transpose(np.asarray(v_full)[:split], (1, 0, 2))
    suffix = np.zeros((P,), np.int32)
    suffix[: len(full) - split] = full[split:]
    logits_suf, k_suf, _ = model.prefill_jit(
        CFG,
        w,
        jnp.asarray(suffix),
        jnp.int32(len(full) - split),
        jnp.asarray(pk),
        jnp.asarray(pv),
        jnp.int32(split),
    )
    np.testing.assert_allclose(np.asarray(logits_suf), np.asarray(logits_full), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(
        np.asarray(k_suf)[: len(full) - split],
        np.asarray(k_full)[split : len(full)],
        rtol=3e-4,
        atol=3e-4,
    )
