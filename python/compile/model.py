"""Layer-2: mini Llama-style decoder in JAX, calling the L1 Pallas kernel.

Build-time only — `aot.py` lowers `prefill` and `decode_step` to HLO text;
the Rust runtime executes them through PJRT. The configuration must match
`rust/src/model/mod.rs::ModelConfig::mini` (the artifact manifest carries it
for a cross-check).

KV layout convention shared with the Rust prefix tree: layers are stacked
along the head axis, so a chunk stores `H = n_layers * heads` "heads" and
layer `l` owns heads `[l*heads, (l+1)*heads)`.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import chunk_attn


@dataclasses.dataclass(frozen=True)
class Config:
    n_layers: int = 2
    d_model: int = 256
    heads: int = 4
    head_dim: int = 64
    ffn_dim: int = 512
    vocab: int = 2048

    @property
    def heads_total(self) -> int:
        return self.n_layers * self.heads


MINI = Config()


def init_weights(cfg: Config, seed: int = 0):
    """PRNG-initialised weights (the 'small real model' stand-in; see
    DESIGN.md §2 — no public checkpoint fits this substrate)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2 + cfg.n_layers)
    scale = 0.02

    def dense(k, shape):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(jnp.float32)

    layers = []
    for li in range(cfg.n_layers):
        lk = jax.random.split(ks[2 + li], 8)
        layers.append(
            dict(
                ln1=jnp.ones((cfg.d_model,), jnp.float32),
                wq=dense(lk[0], (cfg.d_model, cfg.heads * cfg.head_dim)),
                wk=dense(lk[1], (cfg.d_model, cfg.heads * cfg.head_dim)),
                wv=dense(lk[2], (cfg.d_model, cfg.heads * cfg.head_dim)),
                wo=dense(lk[3], (cfg.heads * cfg.head_dim, cfg.d_model)),
                ln2=jnp.ones((cfg.d_model,), jnp.float32),
                w_gate=dense(lk[4], (cfg.d_model, cfg.ffn_dim)),
                w_up=dense(lk[5], (cfg.d_model, cfg.ffn_dim)),
                w_down=dense(lk[6], (cfg.ffn_dim, cfg.d_model)),
            )
        )
    return dict(
        embed=dense(ks[0], (cfg.vocab, cfg.d_model)),
        ln_f=jnp.ones((cfg.d_model,), jnp.float32),
        layers=layers,
    )


def rmsnorm(x, g, eps=1e-5):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope(x, positions):
    """Rotary embedding. x: [..., seq, heads, d]; positions: [..., seq]."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., seq, half]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(layer, x):
    return (jax.nn.silu(x @ layer["w_gate"]) * (x @ layer["w_up"])) @ layer["w_down"]


# --------------------------------------------------------------------------
# Decode step: one token per sequence, TPP attention over the tree context.
# --------------------------------------------------------------------------


def decode_step(cfg: Config, weights, tokens, positions, k_chunks, v_chunks, starts, ends, lens):
    """One batched decode step.

    tokens:    [b] int32 — last generated token per sequence
    positions: [b] int32 — its position (context length before this token)
    k_chunks:  [m, H, c, d] — tree context chunks (H = layers*heads)
    starts/ends/lens: [m] int32 — covered row intervals / fill levels

    Returns (logits [b, vocab], new_k [b, H, d], new_v [b, H, d]) where the
    new rows are the K/V of the *input* tokens, for the coordinator to
    append to the tree.
    """
    h, d = cfg.heads, cfg.head_dim
    x = weights["embed"][tokens]  # [b, dm]
    new_k, new_v = [], []
    for li, layer in enumerate(weights["layers"]):
        xin = rmsnorm(x, layer["ln1"])
        b = xin.shape[0]
        q = (xin @ layer["wq"]).reshape(b, h, d)
        k = (xin @ layer["wk"]).reshape(b, h, d)
        v = (xin @ layer["wv"]).reshape(b, h, d)
        # RoPE expects a seq axis: treat each row as a length-1 sequence.
        q = rope(q[:, None], positions[:, None])[:, 0]
        k = rope(k[:, None], positions[:, None])[:, 0]

        # L1 kernel over this layer's slice of the chunk heads.
        kc = k_chunks[:, li * h : (li + 1) * h]
        vc = v_chunks[:, li * h : (li + 1) * h]
        o, m_acc, n_acc = chunk_attn.tpp_attention_partials(q, kc, vc, starts, ends, lens)
        # The current token attends to itself (its K/V is not in the tree).
        o, m_acc, n_acc = chunk_attn.merge_fresh_row(q, k, v, o, m_acc, n_acc)
        attn = chunk_attn.finalize(o, n_acc).reshape(b, h * d)

        x = x + attn @ layer["wo"]
        x = x + swiglu(layer, rmsnorm(x, layer["ln2"]))
        new_k.append(k)
        new_v.append(v)

    logits = rmsnorm(x, weights["ln_f"]) @ weights["embed"].T
    new_k = jnp.concatenate(new_k, axis=1)  # [b, H, d]
    new_v = jnp.concatenate(new_v, axis=1)
    return logits, new_k, new_v


# --------------------------------------------------------------------------
# Prefill: dense causal attention over (cached prefix ++ suffix), §3.2.
# --------------------------------------------------------------------------


def prefill(cfg: Config, weights, tokens, suffix_len, prefix_k, prefix_v, prefix_len):
    """Prefill the unmatched prompt suffix.

    tokens:    [P] int32 — suffix tokens, zero-padded to the artifact size
    suffix_len: ()  int32 — valid tokens in `tokens`
    prefix_k/v: [H, N, d]  — dense KV of the matched prefix (padded)
    prefix_len: () int32   — valid prefix rows

    Positions are `prefix_len + arange(P)`. Returns
    (logits_last [vocab], new_k [P, H, d], new_v [P, H, d]).
    """
    h, d = cfg.heads, cfg.head_dim
    P = tokens.shape[0]
    N = prefix_k.shape[1]
    positions = prefix_len + jnp.arange(P, dtype=jnp.int32)
    x = weights["embed"][tokens]  # [P, dm]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    suffix_ok = jnp.arange(P, dtype=jnp.int32) < suffix_len  # [P]
    prefix_ok = jnp.arange(N, dtype=jnp.int32) < prefix_len  # [N]
    causal = jnp.arange(P)[:, None] >= jnp.arange(P)[None, :]  # [P, P]

    new_k, new_v = [], []
    for li, layer in enumerate(weights["layers"]):
        xin = rmsnorm(x, layer["ln1"])
        q = rope((xin @ layer["wq"]).reshape(P, h, d)[None], positions[None])[0]
        k = rope((xin @ layer["wk"]).reshape(P, h, d)[None], positions[None])[0]
        v = (xin @ layer["wv"]).reshape(P, h, d)

        pk = jnp.transpose(prefix_k[li * h : (li + 1) * h], (1, 0, 2))  # [N, h, d]
        pv = jnp.transpose(prefix_v[li * h : (li + 1) * h], (1, 0, 2))

        # Scores against prefix rows and causal suffix rows.
        w_pre = jnp.einsum("phd,nhd->hpn", q, pk) * scale  # [h, P, N]
        w_suf = jnp.einsum("phd,nhd->hpn", q, k) * scale  # [h, P, P]
        w_pre = jnp.where(prefix_ok[None, None, :], w_pre, chunk_attn.NEG_INF)
        suf_mask = causal & suffix_ok[None, :]
        w_suf = jnp.where(suf_mask[None], w_suf, chunk_attn.NEG_INF)

        w = jnp.concatenate([w_pre, w_suf], axis=-1)  # [h, P, N+P]
        w = jax.nn.softmax(w, axis=-1)
        vv = jnp.concatenate([pv, v], axis=0)  # [N+P, h, d]
        attn = jnp.einsum("hpn,nhd->phd", w, vv).reshape(P, h * d)

        x = x + attn @ layer["wo"]
        x = x + swiglu(layer, rmsnorm(x, layer["ln2"]))
        new_k.append(k)
        new_v.append(v)

    logits = rmsnorm(x, weights["ln_f"]) @ weights["embed"].T  # [P, vocab]
    last = jnp.clip(suffix_len - 1, 0, P - 1)
    new_k = jnp.concatenate(new_k, axis=1)  # [P, H, d]
    new_v = jnp.concatenate(new_v, axis=1)
    return logits[last], new_k, new_v


# Jitted entry points used by tests (aot.py lowers the raw functions).
decode_step_jit = functools.partial(jax.jit, static_argnums=(0,))(decode_step)
prefill_jit = functools.partial(jax.jit, static_argnums=(0,))(prefill)
