"""Layer-1: the two-phase-partition decode attention kernel in Pallas.

The paper's CUDA kernel partitions thread blocks over (head, chunk) and
batches the query rows of all sequences covered by a chunk (Eqn. 1), merging
partials with online softmax (Eqn. 2). On TPU the same insight maps to
(DESIGN.md §Hardware-Adaptation):

  - the *grid* dimension iterates chunks — the analogue of the chunk
    partition over streaming multiprocessors;
  - one chunk's K/V block (`[h, c, d]`) is staged into VMEM per grid step —
    VMEM plays the role of the CUDA shared memory tile;
  - the batched query×chunk product `[b, d] × [d, c]` is an MXU matmul —
    the tensor-core GEMM the paper gets by turning the query vector into a
    matrix;
  - the online-softmax accumulators `(o, m, n)` live in the revisited
    output blocks across grid steps (the sequential-grid accumulation
    pattern), which is the fused `attn_reduce` of §3.3.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO for both pytest and the AOT
artifacts. Real-TPU performance is estimated from the BlockSpec footprint
in DESIGN.md, not measured.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _tpp_kernel(starts_ref, ends_ref, lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, n_ref):
    """One grid step: fold chunk `i` into the (o, m, n) accumulators."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        n_ref[...] = jnp.zeros_like(n_ref)

    q = q_ref[...]  # [b, h, d]
    k = k_ref[0]  # [h, c, d] — this grid step's chunk
    v = v_ref[0]
    b, h, d = q.shape
    c = k.shape[1]

    start = starts_ref[i]
    end = ends_ref[i]
    length = lens_ref[i]

    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    # partial_attn (Eqn. 1): batched over the covered query rows. The row
    # interval is expressed as a mask so shapes stay static; the MXU matmul
    # below still runs over all b rows (b is small; the win is reading the
    # chunk's K/V once).
    w = jnp.einsum("bhd,hcd->bhc", q, k) * scale  # [b, h, c]
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, 1, 1), 0)
    row_ok = (rows >= start) & (rows < end)
    tok_ok = jax.lax.broadcasted_iota(jnp.int32, (1, 1, c), 2) < length
    visible = row_ok & tok_ok
    w = jnp.where(visible, w, NEG_INF)

    m_c = jnp.max(w, axis=-1)  # [b, h]
    e = jnp.exp(w - m_c[..., None]) * visible.astype(q.dtype)
    n_c = jnp.sum(e, axis=-1)  # [b, h]
    o_c = jnp.einsum("bhc,hcd->bhd", e, v)  # [b, h, d]

    # attn_reduce (Eqn. 2), fused: merge (o_c, m_c, n_c) into the
    # accumulators for the covered rows only.
    m_old = m_ref[...]
    n_old = n_ref[...]
    o_old = o_ref[...]
    active = jnp.squeeze(row_ok, axis=-1)  # [b, 1] broadcast over h
    has_tokens = active & (m_c > NEG_INF / 2)

    m_new = jnp.where(has_tokens, jnp.maximum(m_old, m_c), m_old)
    x = jnp.where(has_tokens, jnp.exp(m_c - m_new), 0.0)
    safe_old = jnp.where(m_old == -jnp.inf, 0.0, jnp.exp(jnp.minimum(m_old - m_new, 0.0)))
    y = jnp.where(has_tokens, safe_old, 1.0)

    o_ref[...] = o_old * y[..., None] + o_c * x[..., None]
    n_ref[...] = n_old * y + n_c * x
    m_ref[...] = m_new


@functools.partial(jax.jit, static_argnames=())
def tpp_attention_partials(q, k_chunks, v_chunks, starts, ends, lens):
    """TPP attention over a tree context; returns unnormalised (o, m, n).

    Shapes: q [b,h,d]; k_chunks/v_chunks [m,h,c,d]; starts/ends/lens [m]
    int32. See `ref.py` for the visibility rule. The chunk metadata is
    passed as full (untiled) inputs — the interpret-mode analogue of scalar
    prefetch.
    """
    b, h, d = q.shape
    m_chunks, hk, c, dk = k_chunks.shape
    assert (h, d) == (hk, dk)

    full = pl.pallas_call(
        _tpp_kernel,
        grid=(m_chunks,),
        in_specs=[
            pl.BlockSpec((m_chunks,), lambda i: (0,)),
            pl.BlockSpec((m_chunks,), lambda i: (0,)),
            pl.BlockSpec((m_chunks,), lambda i: (0,)),
            pl.BlockSpec((b, h, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((1, h, c, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, c, d), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, h, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((b, h), lambda i: (0, 0)),
            pl.BlockSpec((b, h), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, d), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
            jax.ShapeDtypeStruct((b, h), q.dtype),
        ],
        interpret=True,
    )
    return full(starts.astype(jnp.int32), ends.astype(jnp.int32), lens.astype(jnp.int32), q, k_chunks, v_chunks)


def tpp_attention(q, k_chunks, v_chunks, starts, ends, lens):
    """Normalised TPP attention output [b, h, d] (zeros for empty rows)."""
    o, _m, n = tpp_attention_partials(q, k_chunks, v_chunks, starts, ends, lens)
    safe = jnp.maximum(n, 1e-30)[..., None]
    return jnp.where(n[..., None] > 0, o / safe, 0.0)


def merge_fresh_row(q, k_new, v_new, o, m, n):
    """Fold the current token's own K/V row into the partials (Eqn. 2).

    During decode the token being processed is not yet in the tree; its
    K/V row is produced by the same forward pass. Shapes: q/k_new/v_new
    [b, h, d]; (o, m, n) as returned by `tpp_attention_partials`.
    Returns the updated (o, m, n).
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    s = jnp.sum(q * k_new, axis=-1) * scale  # [b, h]
    m_new = jnp.maximum(m, s)
    x = jnp.exp(s - m_new)
    y = jnp.where(jnp.isinf(m), 0.0, jnp.exp(jnp.where(jnp.isinf(m), 0.0, m - m_new)))
    o = o * y[..., None] + v_new * x[..., None]
    n = n * y + x
    return o, m_new, n


def finalize(o, n):
    """o / n with empty-row protection."""
    safe = jnp.maximum(n, 1e-30)[..., None]
    return jnp.where(n[..., None] > 0, o / safe, 0.0)
