"""Pure-jnp oracle for the TPP decode attention kernel.

Given the "tree context" representation the Rust coordinator ships to the
device — stacked KV chunks plus per-chunk (start, end, len) metadata — this
computes dense masked softmax attention in one shot. It is the correctness
reference the Pallas kernel (and transitively the whole serving stack) is
tested against.

Layouts (all fixed-shape, padded):
    q:        [b, h, d]        one query row per sequence (decode step)
    k_chunks: [m, h, c, d]     stacked chunk keys
    v_chunks: [m, h, c, d]     stacked chunk values
    starts:   [m] int32        first covered sequence row (inclusive)
    ends:     [m] int32        last covered sequence row (exclusive);
                               padding chunks have end <= start
    lens:     [m] int32        valid tokens in the chunk (<= c)

A sequence row r attends token t of chunk i iff
    starts[i] <= r < ends[i]  and  t < lens[i].
"""

import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q, k_chunks, v_chunks, starts, ends, lens):
    """Dense reference: softmax(q·Kᵀ/√d)·V over the visible chunk tokens.

    Returns [b, h, d]. Rows that see no tokens return zeros.
    """
    b, h, d = q.shape
    m, hk, c, dk = k_chunks.shape
    assert (h, d) == (hk, dk), f"shape mismatch {q.shape} vs {k_chunks.shape}"

    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    # scores[b, h, m, c]
    scores = jnp.einsum("bhd,mhcd->bhmc", q, k_chunks) * scale

    rows = jnp.arange(b, dtype=jnp.int32)[:, None, None, None]  # [b,1,1,1]
    chunk_rows = (rows >= starts[None, None, :, None]) & (rows < ends[None, None, :, None])
    token_ok = jnp.arange(c, dtype=jnp.int32)[None, None, None, :] < lens[None, None, :, None]
    visible = chunk_rows & token_ok  # [b,1,m,c]

    scores = jnp.where(visible, scores, NEG_INF)
    flat = scores.reshape(b, h, m * c)
    mx = jnp.max(flat, axis=-1, keepdims=True)
    # Rows with no visible tokens: keep numerics finite.
    mx = jnp.maximum(mx, NEG_INF / 2)
    e = jnp.exp(flat - mx)
    e = e * visible.reshape(b, 1, m * c)  # zero out masked exactly
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = jnp.where(denom > 0, e / jnp.maximum(denom, 1e-30), 0.0)
    out = jnp.einsum("bhmc,mhcd->bhd", p.reshape(b, h, m, c), v_chunks)
    return out


def ref_attention_partials(q, k_chunks, v_chunks, starts, ends, lens):
    """Unnormalised online-softmax state (o, m, n) — the form the Pallas
    kernel returns so the model can merge the current token's fresh K/V row
    (Eqn. 2) before normalising.

    Returns (o [b,h,d], m [b,h], n [b,h]) with o = Σ e·V (not divided by n).
    Rows with no visible tokens have m = -inf, n = 0, o = 0.
    """
    b, h, d = q.shape
    m_chunks, _, c, _ = k_chunks.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    scores = jnp.einsum("bhd,mhcd->bhmc", q, k_chunks) * scale
    rows = jnp.arange(b, dtype=jnp.int32)[:, None, None, None]
    chunk_rows = (rows >= starts[None, None, :, None]) & (rows < ends[None, None, :, None])
    token_ok = jnp.arange(c, dtype=jnp.int32)[None, None, None, :] < lens[None, None, :, None]
    visible = chunk_rows & token_ok
    scores = jnp.where(visible, scores, NEG_INF)
    flat = scores.reshape(b, h, m_chunks * c)
    any_visible = jnp.any(visible, axis=(2, 3))  # [b, 1] — broadcast over h
    mx = jnp.max(flat, axis=-1)  # [b, h]
    e = jnp.exp(flat - mx[..., None]) * visible.reshape(b, 1, m_chunks * c)
    n = jnp.sum(e, axis=-1)
    o = jnp.einsum("bhmc,mhcd->bhd", e.reshape(b, h, m_chunks, c), v_chunks)
    mx = jnp.where(any_visible, mx, -jnp.inf)
    n = jnp.where(any_visible, n, 0.0)
    o = jnp.where(any_visible[..., None], o, 0.0)
    return o, mx, n
