"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for the Rust
runtime.

HLO *text* is the interchange format (not serialized protos): jax ≥ 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Weights are passed as runtime parameters, not folded as constants — folding
~1.8M f32 constants into HLO text makes multi-MB artifacts and slow parses.
`aot.py` therefore also writes `mini_weights.bin` (raw little-endian f32,
concatenated in flattened-pytree order) plus `manifest.json` describing the
parameter order, shapes, and artifact inventory; the Rust runtime
cross-checks all of it at load time.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import chunk_attn

# Artifact grid: decode variants ship several batch capacities so the
# coordinator can pick the smallest one that fits the live batch.
DECODE_BATCHES = [1, 2, 4, 8]
MAX_CHUNKS = 48
CHUNK_SIZE = 16
PREFILL_TOKENS = 128  # max prompt-suffix length per prefill call
PREFILL_PREFIX = 128  # max cached-prefix length
KERNEL_TEST_SHAPE = dict(b=4, h=4, c=16, d=64, m=8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def weight_specs(weights):
    """Flattened (path, leaf) list in the order jax flattens the pytree —
    the order the Rust runtime must pass parameter literals in."""
    leaves, _ = jax.tree_util.tree_flatten_with_path(weights)
    out = []
    for path, leaf in leaves:
        name = "/".join(str(p) for p in path)
        out.append((name, leaf))
    return out


def lower_decode(cfg, weights_spec, batch):
    fn = functools.partial(model.decode_step, cfg)
    tokens = jax.ShapeDtypeStruct((batch,), jnp.int32)
    positions = jax.ShapeDtypeStruct((batch,), jnp.int32)
    kc = jax.ShapeDtypeStruct((MAX_CHUNKS, cfg.heads_total, CHUNK_SIZE, cfg.head_dim), jnp.float32)
    meta = jax.ShapeDtypeStruct((MAX_CHUNKS,), jnp.int32)
    return jax.jit(fn).lower(weights_spec, tokens, positions, kc, kc, meta, meta, meta)


def lower_prefill(cfg, weights_spec):
    fn = functools.partial(model.prefill, cfg)
    tokens = jax.ShapeDtypeStruct((PREFILL_TOKENS,), jnp.int32)
    slen = jax.ShapeDtypeStruct((), jnp.int32)
    pk = jax.ShapeDtypeStruct((cfg.heads_total, PREFILL_PREFIX, cfg.head_dim), jnp.float32)
    plen = jax.ShapeDtypeStruct((), jnp.int32)
    return jax.jit(fn).lower(weights_spec, tokens, slen, pk, pk, plen)


def lower_kernel_test():
    """Standalone L1 kernel artifact for the runtime integration test."""
    s = KERNEL_TEST_SHAPE
    q = jax.ShapeDtypeStruct((s["b"], s["h"], s["d"]), jnp.float32)
    kc = jax.ShapeDtypeStruct((s["m"], s["h"], s["c"], s["d"]), jnp.float32)
    meta = jax.ShapeDtypeStruct((s["m"],), jnp.int32)
    fn = lambda q, k, v, st, en, ln: (chunk_attn.tpp_attention(q, k, v, st, en, ln),)
    return jax.jit(fn).lower(q, kc, kc, meta, meta, meta)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--list", action="store_true", help="print artifact inventory and exit")
    args = ap.parse_args()

    cfg = model.MINI
    weights = model.init_weights(cfg, args.seed)
    specs = weight_specs(weights)
    weights_spec = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), weights
    )

    artifacts = []
    for b in DECODE_BATCHES:
        artifacts.append(
            dict(
                file=f"mini_decode_b{b}.hlo.txt",
                kind="decode",
                batch=b,
                max_chunks=MAX_CHUNKS,
                chunk_size=CHUNK_SIZE,
            )
        )
    artifacts.append(
        dict(
            file="mini_prefill.hlo.txt",
            kind="prefill",
            max_suffix=PREFILL_TOKENS,
            max_prefix=PREFILL_PREFIX,
        )
    )
    artifacts.append(dict(file="tpp_kernel_test.hlo.txt", kind="kernel_test", **KERNEL_TEST_SHAPE))

    if args.list:
        for a in artifacts:
            print(json.dumps(a))
        return

    os.makedirs(args.out_dir, exist_ok=True)

    for b in DECODE_BATCHES:
        text = to_hlo_text(lower_decode(cfg, weights_spec, b))
        path = os.path.join(args.out_dir, f"mini_decode_b{b}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    text = to_hlo_text(lower_prefill(cfg, weights_spec))
    with open(os.path.join(args.out_dir, "mini_prefill.hlo.txt"), "w") as f:
        f.write(text)
    print(f"wrote mini_prefill.hlo.txt ({len(text)} chars)")

    text = to_hlo_text(lower_kernel_test())
    with open(os.path.join(args.out_dir, "tpp_kernel_test.hlo.txt"), "w") as f:
        f.write(text)
    print(f"wrote tpp_kernel_test.hlo.txt ({len(text)} chars)")

    # Weights blob + manifest.
    blob = b"".join(np.asarray(leaf, dtype=np.float32).tobytes() for _, leaf in specs)
    with open(os.path.join(args.out_dir, "mini_weights.bin"), "wb") as f:
        f.write(blob)
    manifest = dict(
        model=dict(
            name="mini",
            n_layers=cfg.n_layers,
            d_model=cfg.d_model,
            heads=cfg.heads,
            head_dim=cfg.head_dim,
            ffn_dim=cfg.ffn_dim,
            vocab=cfg.vocab,
            heads_total=cfg.heads_total,
        ),
        seed=args.seed,
        weights_file="mini_weights.bin",
        weights=[dict(name=n, shape=list(l.shape)) for n, l in specs],
        artifacts=artifacts,
    )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(specs)} weight tensors, {len(blob)} bytes)")


if __name__ == "__main__":
    main()
