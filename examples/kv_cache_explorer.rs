//! KV-cache explorer: visualises the prefix tree (Figure 1) and compares
//! memory accounting across the three cache layouts for the same workload.
//!
//! Run: `cargo run --release --example kv_cache_explorer`

use chunk_attention::kvcache::{KvShape, MonolithicKvCache, PagedKvCache, PrefixTree, SeqId};
use chunk_attention::util::stats::fmt_bytes;

fn fill(_pos: usize, token: u32, k: &mut [f32], v: &mut [f32]) {
    k.fill(token as f32);
    v.fill(-(token as f32));
}

fn main() {
    let shape = KvShape::new(8, 64, 8); // c = 8 for a readable tree
    let mut tree = PrefixTree::new(shape);

    // Figure 1's scenario: shared instructions + examples, distinct
    // questions; one sequence is deeper than the others.
    let instructions: Vec<u32> = (10..26).collect(); // 2 full chunks
    let examples: Vec<u32> = (30..42).collect(); // 1.5 chunks
    let prompts: Vec<Vec<u32>> = vec![
        [instructions.clone(), examples.clone(), vec![101, 102, 103]].concat(),
        [instructions.clone(), examples.clone(), vec![201, 202]].concat(),
        [instructions.clone(), vec![90, 91, 92, 93, 94, 95, 96, 97, 301]].concat(),
    ];
    for (i, p) in prompts.iter().enumerate() {
        tree.insert_sequence(SeqId(i as u64), p, &mut fill);
    }
    // Decode a few tokens so private tails appear.
    for step in 0..3u32 {
        for i in 0..3u64 {
            let row = vec![0.0f32; shape.heads * shape.head_dim];
            tree.append_token(SeqId(i), 900 + step * 10 + i as u32, &row, &row);
        }
    }

    println!("=== prefix tree (Figure 1 analogue) ===");
    let ctx = tree.context();
    println!("sequence order: {:?}\n", ctx.seq_order);
    for e in &ctx.entries {
        let chunk = tree.chunk(e.chunk);
        let kind = if e.is_shared() { "SHARED " } else { "private" };
        let toks = chunk.tokens();
        let preview: Vec<u32> = toks.iter().take(4).copied().collect();
        println!(
            "  {kind} chunk {:>3?} rows [{}, {}): {} tokens {:?}{}",
            e.chunk,
            e.start,
            e.end,
            chunk.len(),
            preview,
            if toks.len() > 4 { "…" } else { "" }
        );
    }
    let s = tree.sharing_stats();
    println!(
        "\nsharing: {} logical tokens → {} physical in {} chunks (ratio {:.0}%)",
        s.logical_tokens,
        s.physical_tokens,
        s.chunks,
        s.sharing_ratio() * 100.0
    );

    // Same workload in the three layouts.
    let mut mono = MonolithicKvCache::new(shape);
    let mut paged = PagedKvCache::new(shape, 8);
    let mut paged_shared = PagedKvCache::new(shape, 8);
    for (i, p) in prompts.iter().enumerate() {
        let sid = SeqId(i as u64);
        mono.insert_sequence(sid, p, p.len() + 16, &mut fill);
        paged.insert_sequence(sid, p, &mut fill);
        if i == 0 {
            paged_shared.insert_sequence(sid, p, &mut fill);
        } else {
            paged_shared.insert_sequence_shared(sid, SeqId(0), p, instructions.len(), &mut fill);
        }
    }
    println!("\n=== same workload, three layouts ({} storage) ===", shape.dtype.label());
    println!("  monolithic (Naive/xformers/Flash): {}", fmt_bytes(mono.in_use_bytes()));
    println!("  paged, private pages (PagedAttn):  {}", fmt_bytes(paged.in_use_bytes()));
    println!("  paged, aliased prefix (PagedAttn*): {}", fmt_bytes(paged_shared.in_use_bytes()));
    println!("  prefix tree (ChunkAttention):      {}", fmt_bytes(tree.pool().in_use_bytes()));

    // Capacity gain estimate 1/(1-r) from §3.1.
    let r = s.sharing_ratio();
    println!(
        "\n§3.1 capacity estimate: sharing ratio r={:.2} → ~{:.1}x more concurrent sequences",
        r,
        1.0 / (1.0 - r)
    );
}
