//! Multi-tenant serving scenario (§2.1): several applications share one
//! model; each has a long system prompt (tool definitions, CoT examples,
//! document metadata). Regenerates Table-2-style prompt statistics from
//! the synthetic corpus, then serves a Poisson workload through the engine
//! and reports prefix-cache effectiveness per tenant.
//!
//! Run: `cargo run --release --example multi_tenant_serving`

use chunk_attention::coordinator::engine::testing::SyntheticRunner;
use chunk_attention::coordinator::Engine;
use chunk_attention::util::bench::print_table;
use chunk_attention::util::rng::Pcg64;
use chunk_attention::workload::{Corpus, Request, Tokenizer, Trace, TraceConfig};

fn main() {
    println!("training tokenizer + synthesizing tenant prompts...");
    let tok = Tokenizer::default_english();
    let corpus = Corpus::synthesize(&tok, 4, 900, 2024);

    // Table 2 analogue.
    let rows: Vec<(Vec<String>, String)> = corpus
        .tenants
        .iter()
        .map(|t| {
            (
                vec![
                    format!("tenant-{}", t.id),
                    t.kind.label().to_string(),
                    t.system_tokens.len().to_string(),
                    format!("{:.1}", t.system_prompt.len() as f64 / t.system_tokens.len() as f64),
                ],
                String::new(),
            )
        })
        .collect();
    print_table(
        "Table 2 analogue — synthetic shared system prompts (paper: 879-4257 tokens)",
        &["tenant", "kind", "#shared tokens", "chars/token"],
        &rows,
    );

    // Poisson workload over the tenants (Zipf-skewed popularity).
    let mut rng = Pcg64::seeded(5);
    let trace = Trace::poisson(
        &TraceConfig {
            rps: 100.0,
            n_requests: 24,
            n_tenants: corpus.tenants.len(),
            tenant_skew: 0.9,
            query_tokens: 24,
            completion_tokens: 8,
            seed: 5,
        },
        |tenant, trace_rng| {
            let prompt = corpus.make_request_tokens(&tok, tenant, 24, trace_rng);
            let shared = corpus.tenants[tenant].system_tokens.len();
            (prompt, shared)
        },
    );
    let _ = &mut rng;

    println!("\nserving {} requests across {} tenants...", trace.requests.len(), corpus.tenants.len());
    let mut engine = Engine::new(SyntheticRunner { heads_total: 4, head_dim: 32, vocab: 32000 }, 32, 8);
    for r in &trace.requests {
        engine.submit(Request { ..r.clone() });
    }
    engine.run_to_completion().expect("serve");

    let stats = engine.stats();
    let total_prefill = stats.prefill_tokens_computed + stats.prefill_tokens_reused;
    println!("\nprefix-cache effectiveness:");
    println!("  prompt tokens total:    {total_prefill}");
    println!(
        "  recomputed (prefill):   {} ({:.0}%)",
        stats.prefill_tokens_computed,
        100.0 * stats.prefill_tokens_computed as f64 / total_prefill as f64
    );
    println!(
        "  reused from PAKV:       {} ({:.0}%)",
        stats.prefill_tokens_reused,
        100.0 * stats.prefill_tokens_reused as f64 / total_prefill as f64
    );
    println!("  decode steps:           {}", stats.decode_steps);
    println!("  peak batch:             {}", engine.scheduler().peak_batch());
    let (rebuilds, hits) = engine.tree().context_stats();
    println!("  context rebuilds/hits:  {rebuilds}/{hits} (lazy context copy, §3.3)");
    engine.tree().check_invariants().expect("tree invariants");
    println!("\ndone — tree invariants hold, cache drained.");
}
