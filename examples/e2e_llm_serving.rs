//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Loads the AOT-compiled mini Llama-style model (JAX L2 + Pallas TPP
//! kernel L1, `make artifacts`) through PJRT, then serves a batched
//! multi-tenant Poisson workload with the Rust continuous-batching engine
//! (L3). Python is not involved: the binary only reads `artifacts/*.hlo.txt`.
//!
//! Reports per-request latency, decode throughput, prefix-cache reuse, and
//! KV memory — the §4.2 metrics on the real (small-scale) stack. The run
//! is recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `make artifacts && cargo run --release --example e2e_llm_serving`

use std::time::Instant;

use chunk_attention::coordinator::Engine;
use chunk_attention::runtime::PjrtModel;
use chunk_attention::util::rng::Pcg64;
use chunk_attention::util::stats::{fmt_bytes, Summary};
use chunk_attention::workload::{Request, Trace, TraceConfig};

fn main() -> anyhow::Result<()> {
    chunk_attention::util::logger::init();
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    println!("loading artifacts from {} ...", dir.display());
    let t0 = Instant::now();
    let model = PjrtModel::load(&dir)?;
    println!(
        "  model: {:?} ({} params), chunk_size={}, max_batch={} — loaded in {:.2}s",
        model.manifest().model.name,
        model.manifest().model.param_count(),
        model.chunk_size(),
        model.max_batch(),
        t0.elapsed().as_secs_f64()
    );

    // Workload: 2 tenants with 40-token system prompts, 12 requests,
    // near-simultaneous arrivals, 12 completion tokens each.
    let chunk_size = model.chunk_size();
    let max_batch = model.max_batch().min(8);
    let mut engine = Engine::new(model, chunk_size, max_batch);

    let mut rng = Pcg64::seeded(11);
    let trace = Trace::poisson(
        &TraceConfig {
            rps: 50.0,
            n_requests: 12,
            n_tenants: 2,
            tenant_skew: 0.0,
            query_tokens: 8,
            completion_tokens: 12,
            seed: 11,
        },
        |tenant, trace_rng| {
            // Token ids must stay inside the mini model's vocab (2048).
            let sys: Vec<u32> = (0..40).map(|i| 100 + tenant as u32 * 500 + i).collect();
            let mut p = sys;
            p.extend((0..8).map(|_| trace_rng.below(2000) as u32));
            (p, 40)
        },
    );
    let _ = &mut rng;

    println!("\nserving {} requests (max_batch={max_batch}) ...", trace.requests.len());
    let wall0 = Instant::now();
    for r in &trace.requests {
        engine.submit(Request { ..r.clone() });
    }
    let finished = engine.run_to_completion()?;
    let wall = wall0.elapsed().as_secs_f64();

    let mut lat = Summary::new();
    for f in &finished {
        lat.add(f.normalized_latency_ms_per_tok());
    }
    let stats = engine.stats();
    println!("\n=== e2e results (real PJRT decode path) ===");
    println!("requests finished:        {}", finished.len());
    println!("wall time:                {wall:.2}s");
    println!(
        "decode throughput:        {:.1} tok/s ({} tokens in {} steps)",
        stats.decoded_tokens as f64 / stats.decode_time_s,
        stats.decoded_tokens,
        stats.decode_steps
    );
    println!(
        "normalized latency:       mean {:.1} ms/tok, p99 {:.1} ms/tok",
        lat.mean(),
        lat.percentile(99.0)
    );
    println!(
        "prefill: computed {} tokens, reused {} via prefix lookup ({:.0}% saved)",
        stats.prefill_tokens_computed,
        stats.prefill_tokens_reused,
        100.0 * stats.prefill_tokens_reused as f64
            / (stats.prefill_tokens_computed + stats.prefill_tokens_reused) as f64
    );
    println!(
        "peak KV cache:            {} ({} storage), peak batch {}",
        fmt_bytes(engine.tree().pool().peak_bytes()),
        engine.tree().shape().dtype.label(),
        engine.scheduler().peak_batch()
    );
    // Show one completion to prove real tokens flowed through the model.
    if let Some(c) = engine.completion_of(finished[0].request.id) {
        println!("sample completion (request {}): {:?}", finished[0].request.id, c);
    }
    engine.tree().check_invariants().map_err(|e| anyhow::anyhow!(e))?;
    println!("tree invariants hold; cache drained.");
    println!("\n=== metrics exposition (scrape format) ===");
    print!("{}", chunk_attention::metrics::render_exposition(engine.metrics(), "chunk_attn"));
    Ok(())
}
