//! Quickstart: the PAKV + TPP public API in ~60 lines.
//!
//! Builds a prefix tree, inserts three requests sharing a system prompt,
//! runs the two-phase-partition decode attention, and prints the sharing
//! statistics. Run: `cargo run --release --example quickstart`

use chunk_attention::attention::{tpp_attention_2d, Queries, Tpp2dScratch};
use chunk_attention::kvcache::{KvShape, PrefixTree, SeqId};
use chunk_attention::util::rng::Pcg64;
use chunk_attention::util::threadpool::ThreadPool;

fn main() {
    // 8 heads, 64-dim, 16-token chunks (paper: 32 heads, 128-dim, c=64).
    let shape = KvShape::new(8, 64, 16);
    let mut tree = PrefixTree::new(shape);

    // A 48-token shared system prompt + per-request questions.
    let system_prompt: Vec<u32> = (1000..1048).collect();
    let mut fill = |pos: usize, token: u32, k: &mut [f32], v: &mut [f32]| {
        // Stand-in for the model's KV projection (see examples/e2e_llm_serving
        // for the real PJRT-compiled model).
        let mut rng = Pcg64::new(token as u64, pos as u64);
        rng.fill_uniform_f32(k, -1.0, 1.0);
        rng.fill_uniform_f32(v, -1.0, 1.0);
    };
    for (i, question) in [[1u32, 2, 3], [4, 5, 6], [7, 8, 9]].iter().enumerate() {
        let mut prompt = system_prompt.clone();
        prompt.extend(question);
        let outcome = tree.insert_sequence(SeqId(i as u64), &prompt, &mut fill);
        println!(
            "request {i}: {} prompt tokens, {} reused from the prefix cache",
            outcome.total_tokens, outcome.matched_tokens
        );
    }

    let stats = tree.sharing_stats();
    println!(
        "\nKV cache: {} logical tokens stored as {} physical ({}% deduplicated)",
        stats.logical_tokens,
        stats.physical_tokens,
        (stats.sharing_ratio() * 100.0).round()
    );

    // One decode step: batched queries in tree order, TPP attention.
    let ctx = tree.context();
    let b = ctx.seq_order.len();
    let shared = ctx.shared().count();
    let private = ctx.private().count();
    println!("tree context: {shared} shared chunks (chunk-first phase), {private} private (sequence-first)");

    let mut rng = Pcg64::seeded(7);
    let mut q = vec![0.0f32; shape.heads * b * shape.head_dim];
    rng.fill_uniform_f32(&mut q, -1.0, 1.0);
    let queries = Queries::new(&q, shape.heads, b, shape.head_dim);

    let pool = ThreadPool::default_for_host();
    let mut scratch = Tpp2dScratch::new();
    let mut out = vec![0.0f32; q.len()];
    tpp_attention_2d(&tree, &ctx, &queries, &pool, &mut scratch, &mut out);
    println!("decode step done: output [heads={}, batch={b}, d={}]", shape.heads, shape.head_dim);
    println!("o[0][..4] = {:?}", &out[..4]);

    // Completed sequences give their private chunks back to the pool.
    for i in 0..3 {
        tree.remove_sequence(SeqId(i));
    }
    println!(
        "after completion: {} chunks in use, {} retained in the pool free list",
        tree.pool().in_use(),
        tree.pool().allocated()
    );
}
